//! Analytical memory-footprint model (paper section 2 "Memory Requirement
//! of Parameter-Efficient Finetuning", Figure 6, Table 6 memory column,
//! and the abstract's ">780 GB → <48 GB" headline).
//!
//! Everything here is exact arithmetic over model shapes — the one part of
//! the paper we can reproduce with no simulation at all.

/// LLaMA-family model shapes (Touvron et al. 2023).
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    /// display name (`"7B"` … `"65B"`)
    pub name: &'static str,
    /// residual-stream width
    pub d_model: usize,
    /// transformer block count
    pub n_layers: usize,
    /// attention head count
    pub n_heads: usize,
    /// feed-forward hidden width
    pub d_ff: usize,
    /// vocabulary size
    pub vocab: usize,
}

/// LLaMA 7B shapes.
pub const LLAMA_7B: ModelSpec = ModelSpec {
    name: "7B", d_model: 4096, n_layers: 32, n_heads: 32, d_ff: 11008,
    vocab: 32000,
};
/// LLaMA 13B shapes.
pub const LLAMA_13B: ModelSpec = ModelSpec {
    name: "13B", d_model: 5120, n_layers: 40, n_heads: 40, d_ff: 13824,
    vocab: 32000,
};
/// LLaMA 33B shapes.
pub const LLAMA_33B: ModelSpec = ModelSpec {
    name: "33B", d_model: 6656, n_layers: 60, n_heads: 52, d_ff: 17920,
    vocab: 32000,
};
/// LLaMA 65B shapes.
pub const LLAMA_65B: ModelSpec = ModelSpec {
    name: "65B", d_model: 8192, n_layers: 80, n_heads: 64, d_ff: 22016,
    vocab: 32000,
};

/// The four LLaMA sizes the paper finetunes, smallest first.
pub fn llama_family() -> [ModelSpec; 4] {
    [LLAMA_7B, LLAMA_13B, LLAMA_33B, LLAMA_65B]
}

impl ModelSpec {
    /// Parameters in the linear projections (the quantized part).
    pub fn linear_params(&self) -> usize {
        let (d, f) = (self.d_model, self.d_ff);
        self.n_layers * (4 * d * d + 3 * d * f)
    }

    /// Embedding + head + norms (kept 16-bit).
    pub fn other_params(&self) -> usize {
        2 * self.vocab * self.d_model
            + self.d_model * (2 * self.n_layers + 1)
    }

    /// All parameters (linears + embeddings/head/norms).
    pub fn total_params(&self) -> usize {
        self.linear_params() + self.other_params()
    }

    /// LoRA parameters with adapters of rank r on every linear layer
    /// (paper: "adapters on all linear transformer block layers").
    pub fn lora_params(&self, r: usize) -> usize {
        let (d, f) = (self.d_model, self.d_ff);
        // wq,wk,wv,wo: (d+d)r each; wg,wu: (d+f)r; wd: (f+d)r
        self.n_layers * (4 * (d + d) * r + 3 * (d + f) * r)
    }
}

/// Finetuning strategies compared in Figure 1 / Figure 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// 16-bit full finetuning with 32-bit Adam states + 16-bit grads.
    Full16,
    /// 16-bit base + LoRA adapters.
    LoRA16 { r: usize },
    /// 4-bit base + LoRA; optionally double-quantized constants.
    QLoRA4 { r: usize, double_quant: bool },
}

/// Byte-level breakdown of one finetuning configuration.
#[derive(Debug, Clone)]
pub struct Footprint {
    /// frozen base weights at the strategy's precision
    pub base_weights: usize,
    /// absmax/codebook overhead of quantization (0 for 16-bit)
    pub quant_constants: usize,
    /// LoRA adapter parameters (16-bit)
    pub lora_weights: usize,
    /// gradient storage for whatever is trainable
    pub gradients: usize,
    /// Adam moment vectors (32-bit, trainable params only)
    pub optimizer: usize,
    /// activation/input gradients for batch 1, seq 512, with gradient
    /// checkpointing (Figure 6's setting)
    pub input_grads: usize,
}

impl Footprint {
    /// Sum of every component in bytes.
    pub fn total(&self) -> usize {
        self.base_weights
            + self.quant_constants
            + self.lora_weights
            + self.gradients
            + self.optimizer
            + self.input_grads
    }

    /// Decimal GB (the paper's unit: Vicuna-13B at 16-bit = 26 GB = 2·13e9).
    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / 1e9
    }
}

/// Inference-time (weights-only) footprint — Table 6's "Memory" column.
pub fn weights_footprint(spec: &ModelSpec, strategy: Strategy) -> usize {
    match strategy {
        Strategy::Full16 | Strategy::LoRA16 { .. } => {
            2 * spec.total_params()
        }
        Strategy::QLoRA4 { r, double_quant } => {
            let linear = spec.linear_params();
            let blocks = linear / 64;
            let constants = if double_quant {
                blocks + blocks.div_ceil(256) * 4 + 4
            } else {
                blocks * 4
            };
            linear / 2 + constants + 2 * spec.other_params()
                + 2 * spec.lora_params(r) // adapters stored bf16
        }
    }
}

/// Training footprint per Figure 6: batch size 1, sequence 512, gradient
/// checkpointing on.
pub fn train_footprint(
    spec: &ModelSpec,
    strategy: Strategy,
    seq: usize,
    batch: usize,
) -> Footprint {
    // With checkpointing, the dominant per-sequence term is the input
    // gradients of the LoRA/linear layers: the paper measures ~18 MB per
    // seq-512 sequence for 7B ≈ 2 bytes * seq * d_model * n_layers * c.
    // Solve c from the paper's 7B number: 18 MB / (512*4096*32*2B) ≈ 0.13;
    // we use one checkpoint segment per layer => recompute buffer of one
    // layer's activations (~7 tensors of (seq, d) + 2 of (seq, f)) plus
    // the per-layer boundary activations.
    let act_per_layer_bytes =
        2 * seq * (7 * spec.d_model + 2 * spec.d_ff) * batch;
    let boundary = 2 * seq * spec.d_model * spec.n_layers * batch;
    let input_grads = boundary + act_per_layer_bytes;

    match strategy {
        Strategy::Full16 => Footprint {
            base_weights: 2 * spec.total_params(),
            quant_constants: 0,
            lora_weights: 0,
            gradients: 2 * spec.total_params(),
            // Adam m+v in fp32
            optimizer: 8 * spec.total_params(),
            input_grads,
        },
        Strategy::LoRA16 { r } => Footprint {
            base_weights: 2 * spec.total_params(),
            quant_constants: 0,
            lora_weights: 2 * spec.lora_params(r),
            gradients: 2 * spec.lora_params(r),
            optimizer: 8 * spec.lora_params(r),
            input_grads,
        },
        Strategy::QLoRA4 { r, double_quant } => {
            let linear = spec.linear_params();
            let blocks = linear / 64;
            let constants = if double_quant {
                blocks + blocks.div_ceil(256) * 4 + 4
            } else {
                blocks * 4
            };
            Footprint {
                base_weights: linear / 2 + 2 * spec.other_params(),
                quant_constants: constants,
                lora_weights: 2 * spec.lora_params(r),
                gradients: 2 * spec.lora_params(r),
                optimizer: 8 * spec.lora_params(r),
                input_grads,
            }
        }
    }
}

/// Bits per parameter of quantization-constant overhead (paper section 3).
pub fn constant_overhead_bits(block: usize, double_quant: bool,
                              block2: usize) -> f64 {
    if double_quant {
        8.0 / block as f64 + 32.0 / (block as f64 * block2 as f64)
    } else {
        32.0 / block as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    #[test]
    fn llama_param_counts_roughly_right() {
        // published LLaMA sizes: 6.7B, 13.0B, 32.5B, 65.2B
        for (spec, expect) in llama_family().iter().zip(
            [6.7e9, 13.0e9, 32.5e9, 65.2e9]) {
            let p = spec.total_params() as f64;
            assert!((p / expect - 1.0).abs() < 0.05,
                    "{}: {p} vs {expect}", spec.name);
        }
    }

    #[test]
    fn headline_780gb_to_48gb() {
        // abstract: "regular 16-bit finetuning of a LLaMA 65B parameter
        // model requires more than 780 GB of GPU memory" (weights + grads
        // + optimizer + activations) vs QLoRA < 48 GB.
        let full = train_footprint(&LLAMA_65B, Strategy::Full16, 512, 1);
        assert!(full.total_gb() > 780.0, "full16 {} GB", full.total_gb());
        let qlora = train_footprint(
            &LLAMA_65B,
            Strategy::QLoRA4 { r: 64, double_quant: true },
            512,
            1,
        );
        assert!(qlora.total_gb() < 48.0, "qlora {} GB", qlora.total_gb());
    }

    #[test]
    fn dq_saves_0373_bits_and_3gb_at_65b() {
        let no_dq = constant_overhead_bits(64, false, 256);
        let dq = constant_overhead_bits(64, true, 256);
        assert!((no_dq - 0.5).abs() < 1e-12);
        assert!((dq - 0.127).abs() < 5e-4);
        let saving_bits = no_dq - dq;
        assert!((saving_bits - 0.373).abs() < 5e-4);
        // "approximately 3 GB for a 65B model"
        let saved = saving_bits * LLAMA_65B.total_params() as f64 / 8.0;
        assert!((saved / GB - 3.0).abs() < 0.3, "saved {} GB", saved / GB);
    }

    #[test]
    fn table6_memory_column_shape() {
        // paper Table 6: Guanaco 65B 41 GB, 33B 21 GB, 13B 10 GB, 7B 5 GB
        // (4-bit weights + adapters); our analytic model should land within
        // ~25% of each (the paper's numbers include serving overheads).
        let expect = [(LLAMA_7B, 5.0), (LLAMA_13B, 10.0), (LLAMA_33B, 21.0),
                      (LLAMA_65B, 41.0)];
        for (spec, gb) in expect {
            let b = weights_footprint(
                &spec, Strategy::QLoRA4 { r: 64, double_quant: true });
            let got = b as f64 / GB;
            assert!((got / gb - 1.0).abs() < 0.35,
                    "{}: {got:.1} GB vs paper {gb}", spec.name);
        }
        // and 16-bit models are ~4x bigger (Vicuna 13B: 26 GB)
        let v13 = weights_footprint(&LLAMA_13B, Strategy::Full16) as f64 / GB;
        assert!((v13 / 26.0 - 1.0).abs() < 0.15, "vicuna13 {v13:.1}");
    }

    #[test]
    fn paper_lora_breakdown_7b() {
        // section 2: 7B LLaMA, LoRA 0.2% of base weights ≈ 26 MB at bf16;
        // 4-bit base ≈ 5048 MB.
        // r=5 gives the paper's "0.2% of base weights" adapter budget
        let lora_mb = 2.0 * LLAMA_7B.lora_params(5) as f64 / 1e6;
        assert!(lora_mb > 15.0 && lora_mb < 40.0, "lora {lora_mb} MB");
        let frac = LLAMA_7B.lora_params(5) as f64
            / LLAMA_7B.total_params() as f64;
        assert!((frac - 0.002).abs() < 7e-4, "lora fraction {frac}");
        let base = weights_footprint(
            &LLAMA_7B, Strategy::QLoRA4 { r: 0, double_quant: true });
        let base_mb = base as f64 / 1e6;
        assert!((base_mb / 5048.0 - 1.0).abs() < 0.25, "base {base_mb} MB");
    }

    #[test]
    fn footprint_monotone_in_size_and_strategy() {
        for pair in llama_family().windows(2) {
            let a = train_footprint(&pair[0], Strategy::Full16, 512, 1);
            let b = train_footprint(&pair[1], Strategy::Full16, 512, 1);
            assert!(a.total() < b.total());
        }
        for spec in llama_family() {
            let full = train_footprint(&spec, Strategy::Full16, 512, 1);
            let lora = train_footprint(&spec, Strategy::LoRA16 { r: 64 },
                                       512, 1);
            let qlora = train_footprint(
                &spec, Strategy::QLoRA4 { r: 64, double_quant: true }, 512, 1);
            assert!(full.total() > lora.total());
            assert!(lora.total() > qlora.total());
        }
    }

    #[test]
    fn gradient_checkpointing_claim() {
        // paper section 2: input gradients dominate LoRA weights even with
        // checkpointing (18 MB/seq vs 26 MB total LoRA at 7B — same order)
        let f = train_footprint(
            &LLAMA_7B, Strategy::QLoRA4 { r: 9, double_quant: true }, 512, 1);
        assert!(f.input_grads > f.lora_weights / 2);
    }
}
