//! Data pipeline: tokenizer, synthetic instruction corpora (the stand-ins
//! for the paper's 8 finetuning datasets), OASST-style conversation trees,
//! and the group-by-length batcher (paper Appendix B.2).

pub mod batching;
pub mod dataset;
pub mod synthetic;
pub mod tokenizer;

pub use batching::{Batch, Batcher};
pub use dataset::{ConversationTree, Dataset, Example};
pub use synthetic::{corpus, CorpusKind};
pub use tokenizer::Tokenizer;
