//! Datasets and OASST-style conversation trees.
//!
//! The paper trains Guanaco on OASST1 by selecting the **top-ranked reply
//! at every level of the conversation tree** and finetuning on the full
//! selected conversation (section 5.1). `ConversationTree` models ranked
//! candidate replies per turn; `top_path_example` extracts that path.

use crate::util::rng::Rng;

use super::synthetic::Task;

/// One training example (possibly a flattened multi-turn conversation).
#[derive(Debug, Clone)]
pub struct Example {
    /// the prompt / user side
    pub instruction: String,
    /// the target / assistant side
    pub response: String,
    /// number of conversation turns flattened into this example
    pub turns: usize,
}

/// A named collection of training examples.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// corpus name (e.g. `"oasst1"`, `"oasst1-holdout"`)
    pub kind: String,
    /// the examples, in generation order until shuffled
    pub examples: Vec<Example>,
}

impl Dataset {
    /// Split off a held-out fraction (deterministic).
    pub fn split(mut self, holdout: f64, seed: u64) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut self.examples);
        let n_hold = ((self.examples.len() as f64) * holdout).round() as usize;
        let hold = self.examples.split_off(self.examples.len() - n_hold);
        (
            Dataset { kind: self.kind.clone(), examples: self.examples },
            Dataset { kind: format!("{}-holdout", self.kind), examples: hold },
        )
    }

    /// Truncate to at most n examples (dataset-size ablations, Table 11).
    pub fn take(mut self, n: usize) -> Dataset {
        self.examples.truncate(n);
        self
    }
}

/// A candidate reply with a (crowd-sourced) rank score.
#[derive(Debug, Clone)]
pub struct RankedReply {
    /// the candidate reply text
    pub text: String,
    /// crowd rank score; higher is preferred
    pub score: f64,
    /// whether this candidate is actually correct for the prompt
    pub correct: bool,
}

/// One level of the conversation: a user turn + ranked assistant replies.
#[derive(Debug, Clone)]
pub struct ConversationLevel {
    /// the user turn at this depth
    pub user: String,
    /// candidate assistant replies, scored
    pub replies: Vec<RankedReply>,
}

/// A linear-in-depth conversation tree with ranked branches per level.
#[derive(Debug, Clone)]
pub struct ConversationTree {
    /// turns from root to leaf, each with its ranked candidates
    pub levels: Vec<ConversationLevel>,
}

impl ConversationTree {
    /// Generate a tree: at each level a task prompt and `branching`
    /// candidate replies — the correct one usually ranked highest, with
    /// `noise` probability that ranking is scrambled (annotation noise).
    pub fn generate(
        rng: &mut Rng,
        tasks: &[Task],
        weights: &[f64],
        depth: usize,
        branching: usize,
        noise: f64,
    ) -> ConversationTree {
        let mut levels = Vec::with_capacity(depth);
        for _ in 0..depth {
            let t = tasks[rng.categorical(weights)];
            let (user, correct) = t.generate(rng, false);
            let mut replies = Vec::with_capacity(branching);
            // correct reply: high score unless annotation noise strikes
            let scramble = rng.bool(noise);
            replies.push(RankedReply {
                text: correct.clone(),
                score: if scramble { rng.f64() } else { 0.8 + 0.2 * rng.f64() },
                correct: true,
            });
            for _ in 1..branching {
                replies.push(RankedReply {
                    text: Task::corrupt(rng, &correct),
                    score: 0.6 * rng.f64(),
                    correct: false,
                });
            }
            levels.push(ConversationLevel { user, replies });
        }
        ConversationTree { levels }
    }

    /// Select the top-ranked reply at every level (paper section 5.1) and
    /// flatten the conversation into one training example. Earlier turns
    /// are folded into the instruction; the final top reply is the target.
    pub fn top_path_example(&self) -> Example {
        let mut context = String::new();
        for (i, level) in self.levels.iter().enumerate() {
            let top = level
                .replies
                .iter()
                .max_by(|a, b| a.score.total_cmp(&b.score))
                .expect("non-empty replies");
            if i + 1 == self.levels.len() {
                let instruction = if context.is_empty() {
                    level.user.clone()
                } else {
                    format!("{context};{}", level.user)
                };
                return Example {
                    instruction,
                    response: top.text.clone(),
                    turns: self.levels.len(),
                };
            }
            if !context.is_empty() {
                context.push(';');
            }
            context.push_str(&level.user);
            context.push('=');
            context.push_str(&top.text);
        }
        unreachable!("empty conversation tree")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::Task;

    #[test]
    fn top_path_prefers_highest_score() {
        let tree = ConversationTree {
            levels: vec![ConversationLevel {
                user: "q".into(),
                replies: vec![
                    RankedReply { text: "bad".into(), score: 0.1, correct: false },
                    RankedReply { text: "good".into(), score: 0.9, correct: true },
                ],
            }],
        };
        let ex = tree.top_path_example();
        assert_eq!(ex.response, "good");
        assert_eq!(ex.turns, 1);
    }

    #[test]
    fn multiturn_context_flattened() {
        let mut rng = Rng::new(1);
        let tree = ConversationTree::generate(
            &mut rng, &[Task::Copy], &[1.0], 3, 3, 0.0);
        let ex = tree.top_path_example();
        assert_eq!(ex.turns, 3);
        assert!(ex.instruction.contains('='), "context folded in");
    }

    #[test]
    fn zero_noise_always_selects_correct() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let tree = ConversationTree::generate(
                &mut rng, &[Task::Reverse], &[1.0], 1, 4, 0.0);
            let top = tree.levels[0]
                .replies
                .iter()
                .max_by(|a, b| a.score.total_cmp(&b.score))
                .unwrap();
            assert!(top.correct);
        }
    }

    #[test]
    fn split_partitions() {
        let d = Dataset {
            kind: "t".into(),
            examples: (0..100)
                .map(|i| Example {
                    instruction: format!("i{i}"),
                    response: "r".into(),
                    turns: 1,
                })
                .collect(),
        };
        let (train, hold) = d.split(0.2, 3);
        assert_eq!(train.examples.len(), 80);
        assert_eq!(hold.examples.len(), 20);
    }
}
