//! Synthetic instruction corpora — stand-ins for the paper's 8 finetuning
//! datasets (section 5.1 / Appendix B.1), built from task families a tiny
//! transformer can actually learn. Each corpus controls the axes the
//! paper's data findings are about:
//!
//! * **suitability** — the mixture of task families (FLAN-like corpora are
//!   benchmark-shaped; chat-like corpora are conversational),
//! * **quality** — label-noise rate (distilled datasets are noisier),
//! * **size** — number of examples,
//! * **form** — single-turn vs multi-turn conversation trees (OASST).

use crate::util::rng::Rng;

use super::dataset::{ConversationTree, Dataset, Example};

/// The eight dataset stand-ins (paper Table 5 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CorpusKind {
    /// crowd-ranked conversation trees (highest quality)
    Oasst1,
    /// helpful/harmless chat pairs
    HhRlhf,
    /// GPT-distilled single-turn instructions
    Alpaca,
    /// model-generated instructions (noisiest)
    SelfInstruct,
    /// large distilled instruction set
    UnnaturalInstructions,
    /// benchmark-shaped task mixture
    FlanV2,
    /// open-source chat mixture
    Chip2,
    /// small corpus of long-output examples
    Longform,
}

impl CorpusKind {
    /// All eight corpora, Table 5 order.
    pub fn all() -> [CorpusKind; 8] {
        [
            CorpusKind::Oasst1,
            CorpusKind::HhRlhf,
            CorpusKind::Alpaca,
            CorpusKind::SelfInstruct,
            CorpusKind::UnnaturalInstructions,
            CorpusKind::FlanV2,
            CorpusKind::Chip2,
            CorpusKind::Longform,
        ]
    }

    /// Paper-style lowercase corpus name.
    pub fn name(self) -> &'static str {
        match self {
            CorpusKind::Oasst1 => "oasst1",
            CorpusKind::HhRlhf => "hh-rlhf",
            CorpusKind::Alpaca => "alpaca",
            CorpusKind::SelfInstruct => "self-instruct",
            CorpusKind::UnnaturalInstructions => "unnatural-instructions",
            CorpusKind::FlanV2 => "flan-v2",
            CorpusKind::Chip2 => "chip2",
            CorpusKind::Longform => "longform",
        }
    }

    /// Default corpus size, scaled down from the paper's (Appendix B.1)
    /// keeping relative ordering (OASST1 9k … Unnatural 240k).
    pub fn default_size(self) -> usize {
        match self {
            CorpusKind::Oasst1 => 400,
            CorpusKind::HhRlhf => 1600,
            CorpusKind::Alpaca => 800,
            CorpusKind::SelfInstruct => 1200,
            CorpusKind::UnnaturalInstructions => 2400,
            CorpusKind::FlanV2 => 2400,
            CorpusKind::Chip2 => 1600,
            CorpusKind::Longform => 400,
        }
    }

    /// Label-noise probability (quality axis; distilled corpora noisier).
    pub fn noise(self) -> f64 {
        match self {
            CorpusKind::Oasst1 => 0.00,
            CorpusKind::FlanV2 => 0.01,
            CorpusKind::Alpaca => 0.03,
            CorpusKind::HhRlhf => 0.05,
            CorpusKind::Chip2 => 0.06,
            CorpusKind::Longform => 0.06,
            CorpusKind::UnnaturalInstructions => 0.10,
            CorpusKind::SelfInstruct => 0.18,
        }
    }
}

/// One synthetic task instance: instruction + correct response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// echo the word
    Copy,
    /// reverse the word
    Reverse,
    /// sort the word's letters
    SortLetters,
    /// uppercase the word
    Upper,
    /// last character of the word
    LastChar,
    /// small-integer addition
    Add,
    /// repeat the word n times
    Repeat,
    /// fixed-table fact lookup (fake world knowledge)
    Lookup,
}

const LETTERS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";

/// Fixed lookup table (fake "capital of X" world knowledge).
const LOOKUP: [(&str, &str); 8] = [
    ("zan", "lusaka"),
    ("ter", "opal"),
    ("vor", "mira"),
    ("qued", "sol"),
    ("plim", "vex"),
    ("grun", "tol"),
    ("ost", "kiv"),
    ("drel", "nam"),
];

fn rand_word(rng: &mut Rng, len: usize) -> String {
    (0..len)
        .map(|_| LETTERS[rng.below(LETTERS.len())] as char)
        .collect()
}

impl Task {
    /// One `(instruction, correct response)` instance; `long` doubles word length.
    pub fn generate(self, rng: &mut Rng, long: bool) -> (String, String) {
        let wlen = if long { 8 + rng.below(8) } else { 3 + rng.below(5) };
        match self {
            Task::Copy => {
                let w = rand_word(rng, wlen);
                (format!("copy {w}"), w)
            }
            Task::Reverse => {
                let w = rand_word(rng, wlen);
                let r: String = w.chars().rev().collect();
                (format!("rev {w}"), r)
            }
            Task::SortLetters => {
                let w = rand_word(rng, wlen);
                let mut b: Vec<u8> = w.bytes().collect();
                b.sort_unstable();
                (format!("sort {w}"), String::from_utf8(b).unwrap())
            }
            Task::Upper => {
                let w = rand_word(rng, wlen);
                (format!("up {w}"), w.to_uppercase())
            }
            Task::LastChar => {
                let w = rand_word(rng, wlen);
                let c = w.chars().last().unwrap();
                (format!("last {w}"), c.to_string())
            }
            Task::Add => {
                let a = rng.below(50);
                let b = rng.below(50);
                (format!("add {a} {b}"), format!("{}", a + b))
            }
            Task::Repeat => {
                let w = rand_word(rng, wlen.min(6));
                let n = 2 + rng.below(2);
                (format!("rep{n} {w}"), w.repeat(n))
            }
            Task::Lookup => {
                let (k, v) = LOOKUP[rng.below(LOOKUP.len())];
                (format!("cap {k}"), v.to_string())
            }
        }
    }

    /// Corrupt a response (label noise / low quality).
    pub fn corrupt(rng: &mut Rng, response: &str) -> String {
        if response.is_empty() {
            return rand_word(rng, 3);
        }
        let mut b: Vec<u8> = response.bytes().collect();
        let i = rng.below(b.len());
        b[i] = LETTERS[rng.below(LETTERS.len())];
        String::from_utf8_lossy(&b).into_owned()
    }
}

/// Task mixture per corpus: (benchmark-shaped tasks, chat-shaped tasks).
/// FLAN-like corpora lean toward the "MMLU-proxy" tasks (Add, Lookup,
/// LastChar); chat corpora toward the "Vicuna-proxy" tasks (Copy, Reverse,
/// Sort, Upper, Repeat). This realizes the paper's dataset-suitability
/// finding (strong MMLU ≠ strong chatbot, section 5.3).
fn mixture(kind: CorpusKind) -> Vec<(Task, f64)> {
    use Task::*;
    match kind {
        CorpusKind::FlanV2 => vec![
            (Add, 3.0), (Lookup, 3.0), (LastChar, 2.0), (Upper, 1.0),
            (Copy, 0.5),
        ],
        CorpusKind::UnnaturalInstructions => vec![
            (Add, 2.0), (Lookup, 2.0), (LastChar, 1.5), (SortLetters, 1.0),
            (Copy, 1.0),
        ],
        CorpusKind::Alpaca => vec![
            (Add, 1.5), (Lookup, 1.5), (Copy, 1.5), (Reverse, 1.5),
            (Upper, 1.0), (SortLetters, 1.0),
        ],
        CorpusKind::Oasst1 => vec![
            (Copy, 2.0), (Reverse, 2.0), (SortLetters, 2.0), (Upper, 1.5),
            (Repeat, 1.5), (Lookup, 0.7), (Add, 0.7),
        ],
        CorpusKind::HhRlhf => vec![
            (Copy, 2.0), (Upper, 2.0), (Repeat, 1.0), (Reverse, 1.0),
            (Add, 0.3),
        ],
        CorpusKind::Chip2 => vec![
            (Copy, 1.5), (Reverse, 1.5), (Repeat, 1.5), (SortLetters, 1.0),
            (Add, 0.5),
        ],
        CorpusKind::SelfInstruct => vec![
            (Copy, 1.5), (Reverse, 1.0), (Upper, 1.0), (Add, 0.7),
            (Lookup, 0.5),
        ],
        CorpusKind::Longform => vec![
            (Repeat, 3.0), (Copy, 2.0), (SortLetters, 1.0),
        ],
    }
}

/// Generate a corpus of `size` examples with seed `seed`.
pub fn corpus(kind: CorpusKind, size: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E37));
    let mix = mixture(kind);
    let tasks: Vec<Task> = mix.iter().map(|(t, _)| *t).collect();
    let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
    let long = matches!(kind, CorpusKind::Longform);
    let mut examples = Vec::with_capacity(size);

    if kind == CorpusKind::Oasst1 {
        // conversation trees with ranked replies; train on the top path
        // (paper: "top reply at each level of the conversation tree")
        let mut remaining = size;
        while remaining > 0 {
            let depth = 1 + rng.below(3).min(remaining);
            let tree = ConversationTree::generate(
                &mut rng, &tasks, &weights, depth, 3, kind.noise());
            let ex = tree.top_path_example();
            remaining -= 1;
            examples.push(ex);
        }
    } else {
        for _ in 0..size {
            let t = tasks[rng.categorical(&weights)];
            let (instr, mut resp) = t.generate(&mut rng, long);
            if rng.bool(kind.noise()) {
                resp = Task::corrupt(&mut rng, &resp);
            }
            examples.push(Example { instruction: instr, response: resp,
                                    turns: 1 });
        }
    }
    Dataset { kind: kind.name().to_string(), examples }
}

/// Held-out evaluation suites (benchmark proxies).
pub enum EvalSuite {
    /// MMLU proxy: knowledge/closed-form tasks.
    MmluProxy,
    /// Vicuna proxy: open-form chat-style tasks.
    VicunaProxy,
}

/// Held-out eval examples drawn from the suite's task mixture.
pub fn eval_set(suite: EvalSuite, size: usize, seed: u64) -> Dataset {
    use Task::*;
    let (tasks, weights): (Vec<Task>, Vec<f64>) = match suite {
        EvalSuite::MmluProxy => (
            vec![Add, Lookup, LastChar],
            vec![1.0, 1.0, 1.0],
        ),
        EvalSuite::VicunaProxy => (
            vec![Copy, Reverse, SortLetters, Upper, Repeat],
            vec![1.0, 1.0, 1.0, 1.0, 1.0],
        ),
    };
    let mut rng = Rng::new(seed);
    let examples = (0..size)
        .map(|_| {
            let t = tasks[rng.categorical(&weights)];
            let (i, r) = t.generate(&mut rng, false);
            Example { instruction: i, response: r, turns: 1 }
        })
        .collect();
    Dataset { kind: "eval".to_string(), examples }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_have_requested_size() {
        for kind in CorpusKind::all() {
            let d = corpus(kind, 50, 7);
            assert_eq!(d.examples.len(), 50, "{kind:?}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = corpus(CorpusKind::Alpaca, 20, 1);
        let b = corpus(CorpusKind::Alpaca, 20, 1);
        for (x, y) in a.examples.iter().zip(b.examples.iter()) {
            assert_eq!(x.instruction, y.instruction);
            assert_eq!(x.response, y.response);
        }
        let c = corpus(CorpusKind::Alpaca, 20, 2);
        assert!(a.examples.iter().zip(c.examples.iter())
            .any(|(x, y)| x.instruction != y.instruction));
    }

    #[test]
    fn tasks_are_correct() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let (i, r) = Task::Reverse.generate(&mut rng, false);
            let w = i.strip_prefix("rev ").unwrap();
            assert_eq!(r, w.chars().rev().collect::<String>());
            let (i, r) = Task::Add.generate(&mut rng, false);
            let parts: Vec<usize> = i.strip_prefix("add ").unwrap()
                .split(' ').map(|s| s.parse().unwrap()).collect();
            assert_eq!(r.parse::<usize>().unwrap(), parts[0] + parts[1]);
        }
    }

    #[test]
    fn noise_ordering_matches_quality_axis() {
        assert!(CorpusKind::Oasst1.noise() < CorpusKind::SelfInstruct.noise());
        assert!(CorpusKind::FlanV2.noise() < CorpusKind::SelfInstruct.noise());
    }

    #[test]
    fn oasst_examples_are_multiturn_sometimes() {
        let d = corpus(CorpusKind::Oasst1, 100, 5);
        assert!(d.examples.iter().any(|e| e.turns > 1));
    }
}
