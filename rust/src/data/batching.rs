//! Group-by-length batching (paper Appendix B.2: "we use group-by-length
//! to group examples of similar lengths in the same batch (note this will
//! produce an oscillating loss curve)").
//!
//! Examples are tokenized, sorted by length, chunked into batches, and the
//! *batch order* is shuffled each epoch. Padding is to the model's fixed
//! `seq_len` (AOT graphs have static shapes); the loss mask zeroes pad.

use crate::util::rng::Rng;

use super::dataset::Dataset;
use super::tokenizer::{Tokenizer, PAD};

/// A fixed-shape training batch.
#[derive(Debug, Clone)]
pub struct Batch {
    /// row-major (batch, seq_len)
    pub tokens: Vec<i32>,
    /// loss mask, same shape as `tokens` (0 on pad and — unless `train_on_source` — on the instruction span)
    pub mask: Vec<f32>,
    /// number of rows
    pub batch: usize,
    /// padded row length
    pub seq_len: usize,
    /// unpadded lengths (diagnostics: group-by-length quality)
    pub lens: Vec<usize>,
}

/// Tokenizes a dataset once and serves shuffled fixed-shape epochs.
pub struct Batcher {
    /// the tokenizer used for every example
    pub tokenizer: Tokenizer,
    /// rows per batch
    pub batch: usize,
    /// fixed padded length (the AOT graph's static shape)
    pub seq_len: usize,
    /// whether the loss also covers the instruction span
    pub train_on_source: bool,
    /// encoded (ids, mask) pairs sorted by length
    encoded: Vec<(Vec<i32>, Vec<f32>)>,
}

impl Batcher {
    /// Tokenize and length-sort `dataset` for group-by-length batching.
    pub fn new(
        dataset: &Dataset,
        tokenizer: Tokenizer,
        batch: usize,
        seq_len: usize,
        train_on_source: bool,
    ) -> Batcher {
        let mut encoded: Vec<(Vec<i32>, Vec<f32>)> = dataset
            .examples
            .iter()
            .map(|e| {
                tokenizer.encode_example(
                    &e.instruction,
                    &e.response,
                    seq_len,
                    train_on_source,
                )
            })
            .collect();
        // group-by-length: stable sort by token count
        encoded.sort_by_key(|(ids, _)| ids.len());
        Batcher { tokenizer, batch, seq_len, train_on_source, encoded }
    }

    /// Full batches available per epoch (the ragged tail is dropped).
    pub fn n_batches(&self) -> usize {
        self.encoded.len() / self.batch
    }

    /// Produce one epoch of batches in shuffled *batch* order.
    pub fn epoch(&self, seed: u64) -> Vec<Batch> {
        let nb = self.n_batches();
        let mut order: Vec<usize> = (0..nb).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut order);
        order.into_iter().map(|b| self.make_batch(b)).collect()
    }

    fn make_batch(&self, index: usize) -> Batch {
        let start = index * self.batch;
        let rows = &self.encoded[start..start + self.batch];
        let mut tokens = vec![PAD; self.batch * self.seq_len];
        let mut mask = vec![0f32; self.batch * self.seq_len];
        let mut lens = Vec::with_capacity(self.batch);
        for (r, (ids, m)) in rows.iter().enumerate() {
            lens.push(ids.len());
            let row = &mut tokens[r * self.seq_len..(r + 1) * self.seq_len];
            row[..ids.len()].copy_from_slice(ids);
            let mrow = &mut mask[r * self.seq_len..(r + 1) * self.seq_len];
            mrow[..m.len()].copy_from_slice(m);
        }
        Batch { tokens, mask, batch: self.batch, seq_len: self.seq_len, lens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, Example};
    use crate::util::prop;

    fn mkset(n: usize) -> Dataset {
        Dataset {
            kind: "t".into(),
            examples: (0..n)
                .map(|i| Example {
                    instruction: format!("copy {}", "x".repeat(1 + i % 17)),
                    response: "x".repeat(1 + i % 17),
                    turns: 1,
                })
                .collect(),
        }
    }

    #[test]
    fn batches_have_fixed_shape() {
        let b = Batcher::new(&mkset(37), Tokenizer::new(512), 4, 48, false);
        assert_eq!(b.n_batches(), 9);
        for batch in b.epoch(1) {
            assert_eq!(batch.tokens.len(), 4 * 48);
            assert_eq!(batch.mask.len(), 4 * 48);
        }
    }

    #[test]
    fn grouped_by_length() {
        let b = Batcher::new(&mkset(64), Tokenizer::new(512), 8, 48, false);
        for batch in b.epoch(2) {
            let spread =
                batch.lens.iter().max().unwrap() - batch.lens.iter().min().unwrap();
            assert!(spread <= 4, "length spread {spread} too wide");
        }
    }

    #[test]
    fn epoch_order_is_shuffled_but_content_stable() {
        let b = Batcher::new(&mkset(64), Tokenizer::new(512), 8, 48, false);
        let e1 = b.epoch(1);
        let e2 = b.epoch(2);
        // same multiset of batches (compare sorted first tokens)
        let key = |e: &[Batch]| {
            let mut k: Vec<Vec<i32>> =
                e.iter().map(|b| b.tokens.clone()).collect();
            k.sort();
            k
        };
        assert_eq!(key(&e1), key(&e2));
        // but the order differs (P[identical] = 1/8! with 8 batches)
        assert_ne!(
            e1.iter().map(|b| b.tokens.clone()).collect::<Vec<_>>(),
            e2.iter().map(|b| b.tokens.clone()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn prop_no_supervised_padding() {
        // mask must never supervise PAD positions
        prop::check("no-supervised-pad", 16, |rng| {
            let n = 16 + rng.below(64);
            let b = Batcher::new(&mkset(n), Tokenizer::new(512), 4, 32, false);
            for batch in b.epoch(rng.next_u64()) {
                for i in 0..batch.tokens.len() {
                    if batch.tokens[i] == PAD {
                        assert_eq!(batch.mask[i], 0.0);
                    }
                }
            }
        });
    }
}
