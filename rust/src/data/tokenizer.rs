//! Byte-level tokenizer with special tokens.
//!
//! Vocabulary layout (must stay below the model config's `vocab`):
//!   0 PAD, 1 BOS, 2 EOS, 3 SEP (instruction/response boundary),
//!   4..=259 raw bytes. Model vocabs < 260 (e.g. the tiny configs with
//!   vocab=256) restrict text to ASCII via `fold_ascii`.

/// padding token id
pub const PAD: i32 = 0;
/// beginning-of-sequence token id
pub const BOS: i32 = 1;
/// end-of-sequence token id
pub const EOS: i32 = 2;
/// instruction/response separator token id
pub const SEP: i32 = 3;
/// first raw-byte token id (byte `b` encodes near `BYTE_BASE + b`)
pub const BYTE_BASE: i32 = 4;

/// Byte-level tokenizer bounded by the model's vocab size.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// model vocab size; byte ids are folded into [BYTE_BASE, vocab)
    pub vocab: usize,
}

impl Tokenizer {
    /// A tokenizer for a model with `vocab` ids (must exceed the specials).
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab > BYTE_BASE as usize + 16, "vocab too small");
        Tokenizer { vocab }
    }

    /// Encode text bytes (no specials).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let span = (self.vocab - BYTE_BASE as usize) as i32;
        text.bytes()
            .map(|b| BYTE_BASE + (b as i32 % span))
            .collect()
    }

    /// Decode ids back to text (specials rendered symbolically).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            match id {
                PAD => {}
                BOS => out.push_str("<s>"),
                EOS => out.push_str("</s>"),
                SEP => out.push_str("<sep>"),
                b if b >= BYTE_BASE && (b as usize) < self.vocab => {
                    let byte = (b - BYTE_BASE) as u8;
                    if byte.is_ascii() {
                        out.push(byte as char);
                    } else {
                        out.push('\u{FFFD}');
                    }
                }
                _ => out.push('\u{FFFD}'),
            }
        }
        out
    }

    /// Encode an (instruction, response) pair:
    /// BOS instr SEP response EOS, plus a loss mask. `train_on_source`
    /// additionally supervises the instruction span (paper Table 10
    /// ablation: target-only is better for MMLU).
    pub fn encode_example(
        &self,
        instruction: &str,
        response: &str,
        max_len: usize,
        train_on_source: bool,
    ) -> (Vec<i32>, Vec<f32>) {
        let mut ids = vec![BOS];
        let mut mask = vec![0.0f32];
        for t in self.encode(instruction) {
            ids.push(t);
            mask.push(if train_on_source { 1.0 } else { 0.0 });
        }
        ids.push(SEP);
        mask.push(0.0);
        for t in self.encode(response) {
            ids.push(t);
            mask.push(1.0);
        }
        ids.push(EOS);
        mask.push(1.0);
        ids.truncate(max_len);
        mask.truncate(max_len);
        (ids, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new(512);
        let s = "Hello, QLoRA! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_disjoint_from_bytes() {
        let t = Tokenizer::new(512);
        for id in t.encode("abcXYZ09") {
            assert!(id >= BYTE_BASE);
        }
    }

    #[test]
    fn example_mask_covers_response_only() {
        let t = Tokenizer::new(512);
        let (ids, mask) = t.encode_example("add 1 2", "3", 64, false);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        let sep_pos = ids.iter().position(|&i| i == SEP).unwrap();
        // nothing before+including SEP is supervised
        assert!(mask[..=sep_pos].iter().all(|&m| m == 0.0));
        // everything after SEP is supervised (response + EOS)
        assert!(mask[sep_pos + 1..].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn train_on_source_supervises_instruction() {
        let t = Tokenizer::new(512);
        let (ids, mask) = t.encode_example("q", "a", 64, true);
        let sep_pos = ids.iter().position(|&i| i == SEP).unwrap();
        assert!(mask[1..sep_pos].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn truncation() {
        let t = Tokenizer::new(512);
        let (ids, mask) = t.encode_example(&"x".repeat(100), "y", 16, false);
        assert_eq!(ids.len(), 16);
        assert_eq!(mask.len(), 16);
    }

    #[test]
    fn small_vocab_folds() {
        let t = Tokenizer::new(256);
        for id in t.encode("é\u{00ff}Z") {
            assert!((id as usize) < 256 && id >= BYTE_BASE);
        }
    }
}
