//! Network serving: the HTTP front end over the request-lifecycle
//! scheduler.
//!
//! Three layers, bottom-up (`ARCHITECTURE.md` has the full diagram):
//!
//! * [`json`] — a zero-dependency, panic-free JSON value/parser/writer
//!   for **untrusted** input: depth- and size-limited, typed
//!   [`json::JsonError`]s (`ParseError` / `TypeError` /
//!   `MissingField`), deterministic sorted-key output. The trusted
//!   build-time twin stays in [`crate::util::json`].
//! * [`http`] — minimal HTTP/1.1 request parsing (method / path /
//!   headers / `Content-Length` body, keep-alive) and responses (fixed
//!   length or chunked transfer for streaming), with the 400/404/405/
//!   413 error mapping and the `{"error":{"kind","message"}}` body
//!   contract.
//! * [`server`] — the endpoints (`POST /v1/generate`, `GET /v1/stats`,
//!   `GET /healthz`, `POST /v1/shutdown`) over a scoped worker pool,
//!   bridged to the single-threaded decode loop through
//!   [`crate::engine::ServeDriver`]; client disconnects cancel their
//!   in-flight jobs. Overload control lives here too: load shedding
//!   (`429`/`503` + `Retry-After`), bounded per-job channels, a
//!   connection cap, the slowloris guard, and worker-panic containment
//!   — plus the serving-side fault-injection sites (see
//!   [`crate::util::faults`]).
//!
//! Everything here is plain `std` — no hyper, no serde — per the
//! repo's offline-registry stance.

pub mod http;
pub mod json;
pub mod server;

pub use http::{
    write_error_after, ChunkedWriter, HttpError, HttpRequest, RequestReader,
};
pub use json::{JsonError, JsonValue};
pub use server::{
    decode_generate, done_line, generate_body, outcome_str, should_shed,
    stats_body, token_line, GenerateRequest, HttpServer, ServerConfig,
    StatsCell,
};
