//! Zero-dependency JSON for the network boundary.
//!
//! This is the *untrusted-input* JSON layer: everything arriving over a
//! socket goes through [`parse`], which is depth- and size-limited and
//! returns a typed [`JsonError`] instead of panicking on any input
//! (`rust/tests/prop_json.rs` fuzzes that property over mutated byte
//! soups). The crate's other JSON module, [`crate::util::json`], stays
//! the *trusted* layer for build-time artifacts (manifests, bench
//! output) where an `anyhow` error with context is the right shape.
//!
//! Semantics (mirrored line-for-line by
//! `python/tests/test_serve_mirror.py` against Python's `json`):
//!
//! * objects are [`BTreeMap`]s — writing is deterministic with sorted
//!   keys, matching `json.dumps(..., sort_keys=True)`;
//! * duplicate keys keep the last value (as Python does);
//! * `\uXXXX` escapes decode surrogate pairs; *lone* surrogates are a
//!   [`JsonError::ParseError`] (Python's `json` accepts them — the
//!   mirror test pins this documented divergence);
//! * numbers overflowing f64 (`1e999`) are a `ParseError` (Python
//!   yields `inf` — second pinned divergence); `-0` round-trips with
//!   its sign;
//! * the writer emits UTF-8 directly (`ensure_ascii=False`) and uses
//!   the two-char escapes `\" \\ \b \f \n \r \t`, with `\u00xx` for the
//!   remaining control characters.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum nesting depth [`parse`] accepts (arrays + objects combined).
pub const MAX_DEPTH: usize = 64;
/// Maximum input size in bytes [`parse`] accepts (1 MiB).
pub const MAX_INPUT_BYTES: usize = 1 << 20;

/// A parsed JSON document (numbers are f64, like JavaScript's).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (always finite: the parser rejects overflow)
    Num(f64),
    /// a string (always valid UTF-8)
    Str(String),
    /// an array
    Arr(Vec<JsonValue>),
    /// an object; `BTreeMap` makes writing deterministic (sorted keys)
    Obj(BTreeMap<String, JsonValue>),
}

/// Typed error from parsing or field extraction — the wire maps these
/// onto HTTP 400 bodies (see [`super::http`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    /// The input is not valid JSON (or exceeds the depth/size limits).
    ParseError {
        /// byte offset where parsing stopped
        offset: usize,
        /// what went wrong
        msg: String,
    },
    /// A field exists but has the wrong type.
    TypeError {
        /// the offending field name
        field: String,
        /// what the caller required
        expected: &'static str,
        /// the JSON type actually present
        found: &'static str,
    },
    /// A required field is absent (or `null`).
    MissingField {
        /// the absent field name
        field: String,
    },
}

impl JsonError {
    /// Stable machine-readable kind, used in HTTP error bodies.
    pub fn kind(&self) -> &'static str {
        match self {
            JsonError::ParseError { .. } => "parse_error",
            JsonError::TypeError { .. } => "type_error",
            JsonError::MissingField { .. } => "missing_field",
        }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::ParseError { offset, msg } => {
                write!(f, "invalid JSON at byte {offset}: {msg}")
            }
            JsonError::TypeError { field, expected, found } => {
                write!(f, "field `{field}` must be {expected}, got {found}")
            }
            JsonError::MissingField { field } => {
                write!(f, "missing required field `{field}`")
            }
        }
    }
}

impl std::error::Error for JsonError {}

impl JsonValue {
    /// The JSON type name ("null" / "bool" / "number" / ...).
    pub fn type_name(&self) -> &'static str {
        match self {
            JsonValue::Null => "null",
            JsonValue::Bool(_) => "bool",
            JsonValue::Num(_) => "number",
            JsonValue::Str(_) => "string",
            JsonValue::Arr(_) => "array",
            JsonValue::Obj(_) => "object",
        }
    }

    /// Object field lookup (`None` for non-objects and absent keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Obj(m) => Some(m),
            _ => None,
        }
    }

    fn field(&self, field: &str) -> Result<&JsonValue, JsonError> {
        match self.get(field) {
            Some(JsonValue::Null) | None => {
                Err(JsonError::MissingField { field: field.to_string() })
            }
            Some(v) => Ok(v),
        }
    }

    fn type_err(
        field: &str,
        expected: &'static str,
        found: &JsonValue,
    ) -> JsonError {
        JsonError::TypeError {
            field: field.to_string(),
            expected,
            found: found.type_name(),
        }
    }

    /// Required string field (`null` counts as missing).
    pub fn req_str(&self, field: &str) -> Result<&str, JsonError> {
        let v = self.field(field)?;
        v.as_str().ok_or_else(|| Self::type_err(field, "a string", v))
    }

    /// Optional string field (`null` and absent both read as `None`).
    pub fn opt_str(&self, field: &str) -> Result<Option<&str>, JsonError> {
        match self.get(field) {
            None | Some(JsonValue::Null) => Ok(None),
            Some(v) => Ok(Some(
                v.as_str()
                    .ok_or_else(|| Self::type_err(field, "a string", v))?,
            )),
        }
    }

    /// Optional non-negative integer field. Rejects negatives,
    /// fractions, and magnitudes past 2^53 (not exactly representable).
    pub fn opt_u64(&self, field: &str) -> Result<Option<u64>, JsonError> {
        match self.get(field) {
            None | Some(JsonValue::Null) => Ok(None),
            Some(v) => {
                let err =
                    || Self::type_err(field, "a non-negative integer", v);
                let n = v.as_num().ok_or_else(err)?;
                if n < 0.0 || n != n.trunc() || n > 9.007199254740992e15 {
                    return Err(err());
                }
                Ok(Some(n as u64))
            }
        }
    }

    /// Optional boolean field (`null` and absent both read as `None`).
    pub fn opt_bool(&self, field: &str) -> Result<Option<bool>, JsonError> {
        match self.get(field) {
            None | Some(JsonValue::Null) => Ok(None),
            Some(v) => Ok(Some(
                v.as_bool()
                    .ok_or_else(|| Self::type_err(field, "a bool", v))?,
            )),
        }
    }

    /// Build an object from key/value pairs (later duplicates win).
    pub fn object<K: Into<String>>(
        pairs: impl IntoIterator<Item = (K, JsonValue)>,
    ) -> JsonValue {
        JsonValue::Obj(
            pairs.into_iter().map(|(k, v)| (k.into(), v)).collect(),
        )
    }

    /// Build an array.
    pub fn array(items: impl IntoIterator<Item = JsonValue>) -> JsonValue {
        JsonValue::Arr(items.into_iter().collect())
    }

    /// String value constructor.
    pub fn s(v: impl Into<String>) -> JsonValue {
        JsonValue::Str(v.into())
    }

    /// Number value constructor.
    pub fn n(v: f64) -> JsonValue {
        JsonValue::Num(v)
    }

    /// Bool value constructor.
    pub fn b(v: bool) -> JsonValue {
        JsonValue::Bool(v)
    }
}

// ---------------------------------------------------------------- writer

impl fmt::Display for JsonValue {
    /// Compact deterministic encoding: sorted object keys, no
    /// whitespace, UTF-8 emitted raw — byte-identical to Python's
    /// `json.dumps(v, sort_keys=True, separators=(",", ":"),
    /// ensure_ascii=False)` on the shared corpus (the mirror test's
    /// cross-check). Non-finite numbers (only constructible by hand —
    /// the parser rejects them) encode as `null`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonValue::Null => f.write_str("null"),
            JsonValue::Bool(true) => f.write_str("true"),
            JsonValue::Bool(false) => f.write_str("false"),
            JsonValue::Num(n) => write_num(*n, f),
            JsonValue::Str(s) => write_escaped(s, f),
            JsonValue::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            JsonValue::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(k, f)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_num(n: f64, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    if !n.is_finite() {
        return f.write_str("null");
    }
    // integral values print without a fraction (and -0 keeps its sign,
    // so it round-trips bit-exactly); everything else uses Rust's
    // shortest-roundtrip float formatting
    if n == n.trunc() && n.abs() <= 9.007199254740992e15 {
        write!(f, "{n:.0}")
    } else {
        write!(f, "{n}")
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    f.write_str("\"")?;
    for ch in s.chars() {
        match ch {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

// ---------------------------------------------------------------- parser

/// Parse a complete JSON document under the default limits
/// ([`MAX_DEPTH`], [`MAX_INPUT_BYTES`]). Trailing non-whitespace is an
/// error. Never panics, for any byte sequence.
pub fn parse(input: &[u8]) -> Result<JsonValue, JsonError> {
    parse_with_limits(input, MAX_DEPTH, MAX_INPUT_BYTES)
}

/// [`parse`] with explicit depth / size limits (for tests and callers
/// with tighter budgets).
pub fn parse_with_limits(
    input: &[u8],
    max_depth: usize,
    max_bytes: usize,
) -> Result<JsonValue, JsonError> {
    if input.len() > max_bytes {
        return Err(JsonError::ParseError {
            offset: 0,
            msg: format!(
                "input of {} bytes exceeds the {} byte limit",
                input.len(),
                max_bytes
            ),
        });
    }
    let mut p = Parser { b: input, pos: 0, max_depth };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos < p.b.len() {
        return Err(p.err("trailing data after the document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    max_depth: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::ParseError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        self.skip_ws();
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn lit(
        &mut self,
        word: &'static str,
        v: JsonValue,
    ) -> Result<JsonValue, JsonError> {
        if self.b.get(self.pos..self.pos + word.len())
            == Some(word.as_bytes())
        {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn digits(&mut self) -> Result<(), JsonError> {
        if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            return Err(self.err("expected a digit"));
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        Ok(())
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part: a leading zero takes no more digits (JSON bans
        // 0123), any other digit takes a run
        match self.peek() {
            Some(b'0') => self.pos += 1,
            _ => self.digits()?,
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            self.digits()?;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            self.digits()?;
        }
        let text = self
            .b
            .get(start..self.pos)
            .and_then(|s| std::str::from_utf8(s).ok())
            .unwrap_or_default();
        let n: f64 = match text.parse() {
            Ok(n) => n,
            Err(_) => return Err(self.err(format!("bad number `{text}`"))),
        };
        if !n.is_finite() {
            // Python's json parses this as inf; a serving boundary has
            // no use for a non-finite number, so reject it cleanly
            return Err(
                self.err(format!("number `{text}` does not fit an f64"))
            );
        }
        Ok(JsonValue::Num(n))
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let Some(c) = self.bump() else {
                return Err(self.err("truncated \\u escape"));
            };
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(self.err("bad hex digit in \\u escape")),
            };
            v = (v << 4) | u16::from(d);
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.bump() != Some(b'"') {
            return Err(self.err("expected a string"));
        }
        let mut buf: Vec<u8> = Vec::new();
        loop {
            let Some(c) = self.bump() else {
                return Err(self.err("unterminated string"));
            };
            match c {
                b'"' => break,
                b'\\' => {
                    let Some(e) = self.bump() else {
                        return Err(self.err("unterminated escape"));
                    };
                    match e {
                        b'"' => buf.push(b'"'),
                        b'\\' => buf.push(b'\\'),
                        b'/' => buf.push(b'/'),
                        b'b' => buf.push(0x08),
                        b'f' => buf.push(0x0c),
                        b'n' => buf.push(b'\n'),
                        b'r' => buf.push(b'\r'),
                        b't' => buf.push(b'\t'),
                        b'u' => {
                            let ch = self.unicode_escape()?;
                            let mut tmp = [0u8; 4];
                            buf.extend_from_slice(
                                ch.encode_utf8(&mut tmp).as_bytes(),
                            );
                        }
                        _ => {
                            return Err(self.err(format!(
                                "invalid escape `\\{}`",
                                e as char
                            )))
                        }
                    }
                }
                0x00..=0x1f => {
                    return Err(
                        self.err("raw control character in string")
                    )
                }
                _ => buf.push(c),
            }
        }
        String::from_utf8(buf).map_err(|_| self.err("invalid UTF-8 in string"))
    }

    /// Decode one `\uXXXX` escape (the `\u` already consumed), pairing
    /// surrogates; a lone surrogate is an error, not a replacement char.
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        let cp: u32 = if (0xD800..=0xDBFF).contains(&hi) {
            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                return Err(self.err("lone high surrogate in \\u escape"));
            }
            let lo = self.hex4()?;
            if !(0xDC00..=0xDFFF).contains(&lo) {
                return Err(self.err("invalid low surrogate in \\u escape"));
            }
            0x10000
                + ((u32::from(hi) - 0xD800) << 10)
                + (u32::from(lo) - 0xDC00)
        } else if (0xDC00..=0xDFFF).contains(&hi) {
            return Err(self.err("lone low surrogate in \\u escape"));
        } else {
            u32::from(hi)
        };
        char::from_u32(cp)
            .ok_or_else(|| self.err("invalid code point in \\u escape"))
    }

    /// Containers at nesting depth `max_depth` are rejected, so at most
    /// `max_depth` arrays/objects ever sit on the recursion stack
    /// (scalars inside the deepest container are fine).
    fn check_depth(&self, depth: usize) -> Result<(), JsonError> {
        if depth >= self.max_depth {
            return Err(self.err(format!(
                "nesting exceeds the depth limit of {}",
                self.max_depth
            )));
        }
        Ok(())
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        self.check_depth(depth)?;
        self.pos += 1; // consume '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => {}
                Some(b']') => return Ok(JsonValue::Arr(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        self.check_depth(depth)?;
        self.pos += 1; // consume '{'
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            if self.bump() != Some(b':') {
                return Err(self.err("expected `:` after object key"));
            }
            // duplicate keys: last one wins, as in Python's json
            map.insert(key, self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => {}
                Some(b'}') => return Ok(JsonValue::Obj(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> JsonValue {
        parse(s.as_bytes()).unwrap()
    }

    #[test]
    fn scalars_roundtrip() {
        for doc in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            assert_eq!(p(doc).to_string(), doc);
        }
    }

    #[test]
    fn nested_roundtrip_sorted_keys() {
        let v = p(r#"{"b": [1, 2, {"x": null}], "a": "y"}"#);
        assert_eq!(v.to_string(), r#"{"a":"y","b":[1,2,{"x":null}]}"#);
    }

    #[test]
    fn duplicate_keys_last_wins() {
        assert_eq!(p(r#"{"k":1,"k":2}"#).to_string(), r#"{"k":2}"#);
    }

    #[test]
    fn escapes_decode_and_reencode() {
        let v = p(r#""a\n\t\"\\\/\b\fAé""#);
        assert_eq!(v.as_str(), Some("a\n\t\"\\/\u{8}\u{c}Aé"));
        assert_eq!(v.to_string(), "\"a\\n\\t\\\"\\\\/\\b\\fAé\"");
    }

    #[test]
    fn surrogate_pairs_combine() {
        assert_eq!(p(r#""😀""#).as_str(), Some("😀"));
    }

    #[test]
    fn lone_surrogates_rejected() {
        for doc in [r#""\ud83d""#, r#""\ud83dx""#, r#""\udc00""#] {
            assert!(matches!(
                parse(doc.as_bytes()),
                Err(JsonError::ParseError { .. })
            ));
        }
    }

    #[test]
    fn number_edges() {
        assert!(matches!(
            parse(b"1e999"),
            Err(JsonError::ParseError { .. })
        ));
        // -0 keeps its sign bit across a round trip
        let v = p("-0");
        assert_eq!(v.to_string(), "-0");
        assert!(matches!(v, JsonValue::Num(n) if n == 0.0
            && n.is_sign_negative()));
        // leading zeros and bare fractions are not JSON
        for bad in ["01", ".5", "1.", "1e", "+1", "--1", "1e+"] {
            assert!(parse(bad.as_bytes()).is_err(), "{bad}");
        }
        assert_eq!(p("1e3"), JsonValue::Num(1000.0));
    }

    #[test]
    fn depth_limit_enforced() {
        let deep_ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse(deep_ok.as_bytes()).is_ok());
        let deep_bad =
            "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(parse(deep_bad.as_bytes()).is_err());
    }

    #[test]
    fn size_limit_enforced() {
        let big = format!("\"{}\"", "x".repeat(MAX_INPUT_BYTES));
        assert!(parse(big.as_bytes()).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(parse(b"1 2").is_err());
        assert!(parse(b"{} x").is_err());
        assert!(parse(b"1 \n ").is_ok());
    }

    #[test]
    fn invalid_utf8_rejected() {
        assert!(parse(b"\"\xff\"").is_err());
        assert!(parse(b"\xff").is_err());
    }

    #[test]
    fn typed_extraction() {
        let v = p(r#"{"s":"x","n":3,"b":true,"z":null,"f":1.5,"neg":-1}"#);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.opt_u64("n").unwrap(), Some(3));
        assert_eq!(v.opt_bool("b").unwrap(), Some(true));
        // null reads as absent for optionals, missing for requireds
        assert_eq!(v.opt_str("z").unwrap(), None);
        assert!(matches!(
            v.req_str("z"),
            Err(JsonError::MissingField { .. })
        ));
        assert!(matches!(
            v.req_str("gone"),
            Err(JsonError::MissingField { .. })
        ));
        assert!(matches!(
            v.req_str("n"),
            Err(JsonError::TypeError { expected: "a string", .. })
        ));
        // non-integers and negatives are type errors for u64 fields
        assert!(v.opt_u64("f").is_err());
        assert!(v.opt_u64("neg").is_err());
        assert!(v.opt_u64("s").is_err());
        assert_eq!(v.opt_u64("gone").unwrap(), None);
    }

    #[test]
    fn error_kinds_and_display() {
        let e = parse(b"[").unwrap_err();
        assert_eq!(e.kind(), "parse_error");
        assert!(e.to_string().contains("invalid JSON"));
        let v = p(r#"{"a":1}"#);
        assert_eq!(v.req_str("a").unwrap_err().kind(), "type_error");
        assert_eq!(v.req_str("b").unwrap_err().kind(), "missing_field");
    }

    #[test]
    fn constructors_build_documents() {
        let v = JsonValue::object([
            ("b", JsonValue::n(2.0)),
            ("a", JsonValue::array([JsonValue::b(true), JsonValue::Null])),
            ("s", JsonValue::s("hé")),
        ]);
        assert_eq!(v.to_string(), r#"{"a":[true,null],"b":2,"s":"hé"}"#);
    }

    #[test]
    fn non_finite_writes_null() {
        assert_eq!(JsonValue::n(f64::NAN).to_string(), "null");
        assert_eq!(JsonValue::n(f64::INFINITY).to_string(), "null");
    }
}
