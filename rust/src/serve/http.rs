//! Minimal HTTP/1.1 over raw [`std::io`] streams — just enough protocol
//! for the serving endpoints, hand-rolled because the offline registry
//! has no hyper/axum (the same zero-dependency stance as
//! [`crate::util::cli`] and [`crate::util::bench`]).
//!
//! Requests: method + path + headers + `Content-Length` body, with
//! keep-alive (HTTP/1.1 default, `Connection: close` honoured) and hard
//! limits on head and body size. Responses: fixed-length bodies
//! ([`write_response`]) or chunked transfer encoding ([`ChunkedWriter`])
//! for token streaming. Error mapping lives here so every failure mode
//! has exactly one status: malformed syntax → 400, oversized body →
//! 413; the router in [`super::server`] adds 404/405, and overload
//! shedding emits 429/503 with a `Retry-After` header via
//! [`write_error_after`].
//!
//! The parser state machine (buffer until `\r\n\r\n`, split head,
//! drain `Content-Length` bytes) is mirrored line-for-line by
//! `python/tests/test_serve_mirror.py`.

use std::io::{self, Read, Write};

use super::json::JsonValue;

/// Largest request head (request line + headers) accepted, in bytes.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Default largest request body accepted, in bytes.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    /// Request method, as sent (`GET`, `POST`, ...).
    pub method: String,
    /// Request target, as sent (path plus any query string).
    pub path: String,
    /// Headers in arrival order; names matched case-insensitively.
    pub headers: Vec<(String, String)>,
    /// The `Content-Length` body (empty when the header is absent).
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response.
    pub keep_alive: bool,
}

impl HttpRequest {
    /// First header with this name (case-insensitive), trimmed.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read. [`HttpError::status`] maps the
/// protocol-level cases onto response codes.
#[derive(Debug)]
pub enum HttpError {
    /// Peer closed the connection cleanly between requests (the normal
    /// end of a keep-alive session — not an error to report).
    Closed,
    /// The socket read timed out with no complete request buffered;
    /// the caller may poll a shutdown flag and retry.
    TimedOut,
    /// Malformed request syntax (→ 400).
    BadRequest(String),
    /// `Content-Length` exceeds the body limit (→ 413).
    PayloadTooLarge(String),
    /// Transport failure mid-request.
    Io(io::Error),
}

impl HttpError {
    /// The response status for protocol-level errors (400/413); `None`
    /// for `Closed`/`TimedOut`/`Io`, where no response can or should be
    /// written.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::BadRequest(_) => Some(400),
            HttpError::PayloadTooLarge(_) => Some(413),
            _ => None,
        }
    }

    /// Human-readable detail for the error body.
    pub fn message(&self) -> String {
        match self {
            HttpError::Closed => "connection closed".to_string(),
            HttpError::TimedOut => "read timed out".to_string(),
            HttpError::BadRequest(m) => m.clone(),
            HttpError::PayloadTooLarge(m) => m.clone(),
            HttpError::Io(e) => e.to_string(),
        }
    }
}

/// Incremental request reader over one connection. Keeps a carry-over
/// buffer so pipelined bytes after one request's body are not lost for
/// the next ([`RequestReader::next_request`] is called once per
/// keep-alive round).
pub struct RequestReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    max_body: usize,
}

impl<R: Read> RequestReader<R> {
    /// A reader enforcing `max_body` bytes per request body.
    pub fn new(inner: R, max_body: usize) -> RequestReader<R> {
        RequestReader { inner, buf: Vec::new(), max_body }
    }

    /// Whether a partial request is sitting in the carry-over buffer.
    /// After a [`HttpError::TimedOut`] this distinguishes an *idle*
    /// keep-alive connection (safe to keep polling) from a slowloris
    /// peer dribbling half a head (the server drops those after its
    /// header deadline instead of pinning a worker forever).
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Pull more bytes from the transport into the carry-over buffer.
    /// Returns the byte count (0 = EOF).
    fn fill(&mut self) -> Result<usize, HttpError> {
        let mut tmp = [0u8; 4096];
        loop {
            match self.inner.read(&mut tmp) {
                Ok(n) => {
                    self.buf.extend_from_slice(
                        tmp.get(..n).unwrap_or_default(),
                    );
                    return Ok(n);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(HttpError::TimedOut)
                }
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
    }

    /// Read one full request (head + body). Blocks until the peer sends
    /// one, the read times out, or the connection ends.
    pub fn next_request(&mut self) -> Result<HttpRequest, HttpError> {
        // 1. accumulate until the blank line ending the head
        let head_end = loop {
            if let Some(i) =
                self.buf.windows(4).position(|w| w == b"\r\n\r\n")
            {
                break i;
            }
            if self.buf.len() > MAX_HEAD_BYTES {
                return Err(HttpError::BadRequest(format!(
                    "request head exceeds {MAX_HEAD_BYTES} bytes"
                )));
            }
            if self.fill()? == 0 {
                return if self.buf.is_empty() {
                    Err(HttpError::Closed)
                } else {
                    Err(HttpError::BadRequest(
                        "connection closed mid-request".to_string(),
                    ))
                };
            }
        };
        // 2. split the head off the carry-over buffer
        let rest = self.buf.split_off(head_end + 4);
        let mut head_bytes = std::mem::replace(&mut self.buf, rest);
        head_bytes.truncate(head_end);
        let head = String::from_utf8(head_bytes).map_err(|_| {
            HttpError::BadRequest("request head is not UTF-8".to_string())
        })?;
        let mut req = parse_head(&head)?;
        // 3. body: exactly Content-Length bytes (chunked uploads are out
        // of scope for this API)
        if req.header("transfer-encoding").is_some() {
            return Err(HttpError::BadRequest(
                "chunked request bodies are not supported".to_string(),
            ));
        }
        let body_len = match req.header("content-length") {
            None => 0,
            Some(v) => v.trim().parse::<usize>().map_err(|_| {
                HttpError::BadRequest(format!(
                    "invalid Content-Length `{v}`"
                ))
            })?,
        };
        if body_len > self.max_body {
            return Err(HttpError::PayloadTooLarge(format!(
                "body of {body_len} bytes exceeds the {} byte limit",
                self.max_body
            )));
        }
        while self.buf.len() < body_len {
            if self.fill()? == 0 {
                return Err(HttpError::BadRequest(
                    "connection closed mid-body".to_string(),
                ));
            }
        }
        let rest = self.buf.split_off(body_len);
        req.body = std::mem::replace(&mut self.buf, rest);
        Ok(req)
    }
}

/// Parse the request head (everything before the blank line).
/// Split out (and pub) so the mirror test and fuzz corpus can hit the
/// state machine without a socket.
pub fn parse_head(head: &str) -> Result<HttpRequest, HttpError> {
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None)
                if !m.is_empty() && !p.is_empty() =>
            {
                (m, p, v)
            }
            _ => {
                return Err(HttpError::BadRequest(format!(
                    "malformed request line `{request_line}`"
                )))
            }
        };
    let keep_alive_default = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => {
            return Err(HttpError::BadRequest(format!(
                "unsupported protocol version `{version}`"
            )))
        }
    };
    let mut headers = Vec::new();
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::BadRequest(format!(
                "malformed header line `{line}`"
            )));
        };
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(HttpError::BadRequest(format!(
                "malformed header name `{name}`"
            )));
        }
        headers.push((name.to_string(), value.trim().to_string()));
    }
    let mut req = HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: Vec::new(),
        keep_alive: keep_alive_default,
    };
    if let Some(c) = req.header("connection") {
        if c.eq_ignore_ascii_case("close") {
            req.keep_alive = false;
        } else if c.eq_ignore_ascii_case("keep-alive") {
            req.keep_alive = true;
        }
    }
    Ok(req)
}

/// Reason phrase for the statuses this server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one fixed-length response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
         Connection: {}\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// The JSON error contract: every non-2xx response body is
/// `{"error":{"kind":...,"message":...}}`.
pub fn error_body(kind: &str, message: &str) -> Vec<u8> {
    JsonValue::object([(
        "error",
        JsonValue::object([
            ("kind", JsonValue::s(kind)),
            ("message", JsonValue::s(message)),
        ]),
    )])
    .to_string()
    .into_bytes()
}

/// Write one error response under the JSON error contract.
pub fn write_error<W: Write>(
    w: &mut W,
    status: u16,
    kind: &str,
    message: &str,
    keep_alive: bool,
) -> io::Result<()> {
    write_response(
        w,
        status,
        "application/json",
        &error_body(kind, message),
        keep_alive,
    )
}

/// Write one error response under the JSON error contract plus a
/// `Retry-After` header — the overload-shedding shape (429 on queue
/// pressure, 503 while draining): the client learns both *that* it was
/// turned away and *when* to come back.
pub fn write_error_after<W: Write>(
    w: &mut W,
    status: u16,
    kind: &str,
    message: &str,
    retry_after_secs: u64,
    keep_alive: bool,
) -> io::Result<()> {
    let body = error_body(kind, message);
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nRetry-After: {}\r\nConnection: {}\r\n\r\n",
        status,
        status_text(status),
        body.len(),
        retry_after_secs,
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(&body)?;
    w.flush()
}

/// Chunked-transfer response writer for token streaming. `begin` sends
/// the header, [`ChunkedWriter::chunk`] one chunk per call (each flushed
/// immediately — a dead peer surfaces as an `Err` here, which the server
/// routes into the request's cancel handle), and
/// [`ChunkedWriter::finish`] the terminating chunk.
pub struct ChunkedWriter<'w, W: Write> {
    w: &'w mut W,
}

impl<'w, W: Write> ChunkedWriter<'w, W> {
    /// Send the response head announcing chunked transfer encoding.
    pub fn begin(
        w: &'w mut W,
        status: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> io::Result<ChunkedWriter<'w, W>> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n\
             Transfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
            status,
            status_text(status),
            content_type,
            if keep_alive { "keep-alive" } else { "close" },
        );
        w.write_all(head.as_bytes())?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Send one chunk. Empty payloads are skipped (a zero-length chunk
    /// would terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Send the terminating zero-length chunk.
    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read_one(raw: &[u8]) -> Result<HttpRequest, HttpError> {
        RequestReader::new(raw, MAX_BODY_BYTES).next_request()
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let req = read_one(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive);
        assert_eq!(req.header("HOST"), Some("x"));
    }

    #[test]
    fn keep_alive_rules() {
        let close = read_one(
            b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        assert!(!close.keep_alive);
        let old = read_one(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!old.keep_alive);
        let revived = read_one(
            b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n",
        )
        .unwrap();
        assert!(revived.keep_alive);
    }

    #[test]
    fn pipelined_requests_both_parse() {
        let raw: &[u8] = b"GET /healthz HTTP/1.1\r\n\r\n\
                           GET /v1/stats HTTP/1.1\r\n\r\n";
        let mut rd = RequestReader::new(raw, MAX_BODY_BYTES);
        assert_eq!(rd.next_request().unwrap().path, "/healthz");
        assert_eq!(rd.next_request().unwrap().path, "/v1/stats");
        assert!(matches!(rd.next_request(), Err(HttpError::Closed)));
    }

    #[test]
    fn malformed_heads_are_400() {
        for raw in [
            b"GARBAGE\r\n\r\n".as_slice(),
            b"GET /\r\n\r\n".as_slice(),
            b"GET / HTTP/2.0\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1\r\nno-colon\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1\r\nbad name: x\r\n\r\n".as_slice(),
            b"GET / HTTP/1.1 extra\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\nContent-Length: zz\r\n\r\n".as_slice(),
        ] {
            let err = read_one(raw).unwrap_err();
            assert_eq!(err.status(), Some(400), "{err:?}");
        }
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n";
        let err = RequestReader::new(raw.as_slice(), 10)
            .next_request()
            .unwrap_err();
        assert_eq!(err.status(), Some(413));
    }

    #[test]
    fn truncated_requests_fail_cleanly() {
        assert!(matches!(
            read_one(b"GET / HT"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            read_one(b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(read_one(b""), Err(HttpError::Closed)));
    }

    #[test]
    fn fixed_response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn error_body_contract() {
        let body = error_body("parse_error", "broken");
        assert_eq!(
            String::from_utf8(body).unwrap(),
            r#"{"error":{"kind":"parse_error","message":"broken"}}"#
        );
        let mut out = Vec::new();
        write_error(&mut out, 404, "not_found", "no such route", false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains(r#""kind":"not_found""#));
    }

    #[test]
    fn retry_after_wire_format() {
        assert_eq!(status_text(429), "Too Many Requests");
        let mut out = Vec::new();
        write_error_after(&mut out, 429, "overloaded", "queue full", 2, true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.contains(r#""kind":"overloaded""#));
        let mut out = Vec::new();
        write_error_after(&mut out, 503, "draining", "shutting down", 1, false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains(r#""kind":"draining""#));
    }

    #[test]
    fn partial_buffer_is_visible_for_the_slowloris_guard() {
        // a reader over a half-sent head: the source runs dry, and the
        // carry-over buffer reports a partial request
        let mut rd = RequestReader::new(b"GET / HT".as_slice(), MAX_BODY_BYTES);
        assert!(!rd.has_partial(), "fresh reader has no carry-over");
        assert!(rd.next_request().is_err());
        // an in-memory slice signals EOF (BadRequest) rather than
        // TimedOut, but the buffered half-head is still observable
        assert!(rd.has_partial(), "half a head is buffered");
    }

    #[test]
    fn chunked_stream_wire_format() {
        let mut out = Vec::new();
        {
            let mut cw = ChunkedWriter::begin(
                &mut out,
                200,
                "application/jsonl",
                false,
            )
            .unwrap();
            cw.chunk(b"hello ").unwrap();
            cw.chunk(b"").unwrap(); // skipped, not a terminator
            cw.chunk(b"world").unwrap();
            cw.finish().unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body, "6\r\nhello \r\n5\r\nworld\r\n0\r\n\r\n");
    }
}
