//! The HTTP serving front end: a [`TcpListener`] + scoped worker pool
//! in front of [`Session::serve_loop`].
//!
//! Threading model: the PJRT runtime is single-threaded (`Engine` holds
//! an `Rc<Runtime>`), so the decode loop stays on the thread that calls
//! [`HttpServer::run`]. Worker threads own the sockets: they parse
//! requests, push jobs into a condvar-guarded inbox, and block on a
//! per-job channel for events. The decode thread drains the inbox
//! between steps (via [`crate::engine::ServeDriver`]) and routes
//! per-token / per-completion events back to the owning worker. A
//! client disconnect surfaces as a write error on the worker, which
//! flips the job's [`CancelHandle`]; the scheduler frees the row within
//! one step.
//!
//! Overload control and fault containment (`ARCHITECTURE.md` §"Failure
//! domains & overload policy" has the decision table):
//!
//! * **Load shedding** — [`should_shed`] turns `POST /v1/generate` away
//!   with `429` + `Retry-After` once queue depth or resident-token
//!   pressure crosses the [`ServerConfig`] watermarks; requests during
//!   the shutdown drain get a structured `503 {"error":{"kind":
//!   "draining"}}` instead of a reset connection.
//! * **Bounded channels** — per-job token channels are
//!   [`mpsc::sync_channel`]s; a consumer too slow to drain its own
//!   tokens backpressures into [`CancelHandle`] cancellation instead of
//!   unbounded buffering, and the decode thread never blocks on a send.
//! * **Connection cap + slowloris guard** — excess connections are
//!   turned away with `503`, and a peer dribbling half a request head
//!   past [`ServerConfig::header_deadline`] is dropped (408) instead of
//!   pinning a worker forever.
//! * **Worker-panic containment** — a panicking connection handler is
//!   caught at the worker boundary ([`std::panic::catch_unwind`]); the
//!   worker re-enters its accept loop (counted in
//!   `ServerStats::worker_restarts`) and the shared inbox lock
//!   recovers from poisoning, so one panic never wedges the server.
//! * **Fault injection** — [`crate::util::faults::Faults`] sites
//!   (`slow-write`, `conn-reset`, `worker-panic`) fire here under a
//!   seeded plan; zero-cost when disabled.
//!
//! Endpoints (`ARCHITECTURE.md` has the full table and flow diagram):
//!
//! | route              | method | body                                    |
//! |--------------------|--------|-----------------------------------------|
//! | `/v1/generate`     | POST   | prompt [, adapter, priority, deadline_ms, max_new_tokens, stream] |
//! | `/v1/stats`        | GET    | scheduler + KV-block statistics          |
//! | `/healthz`         | GET    | liveness                                 |
//! | `/v1/shutdown`     | POST   | drain in-flight work and stop            |
//!
//! Request decoding ([`decode_generate`]) and response encoding
//! ([`stats_body`], [`outcome_str`]) are pure functions, mirrored
//! line-for-line by `python/tests/test_serve_mirror.py`.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{
    CancelHandle, GenRequest, JobOutcome, Priority, Sampler, ServeDriver,
    ServeEvent, ServeReport, ServerStats, Session, SourcePoll,
};
use crate::util::faults::{FaultSite, Faults};

use super::http::{
    self, ChunkedWriter, HttpError, HttpRequest, RequestReader,
};
use super::json::{JsonError, JsonValue};

/// Configuration for [`HttpServer::bind`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port — read it
    /// back via [`HttpServer::local_addr`]).
    pub addr: String,
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Per-request body size limit in bytes.
    pub max_body_bytes: usize,
    /// Concurrent-connection cap; excess accepts are answered with a
    /// `503` + `Retry-After` and closed before a worker is tied up.
    pub max_connections: usize,
    /// Queue-depth watermark: a `/v1/generate` arriving while
    /// `pending + scheduler queue depth` is at or above this is shed
    /// with `429` + `Retry-After` (see [`should_shed`]).
    pub max_queue: usize,
    /// Bound of each job's token event channel. A streaming consumer
    /// that falls this many tokens behind is cancelled instead of
    /// buffering without bound.
    pub token_channel_depth: usize,
    /// Per-request wall-clock cap, mapped onto the scheduler deadline
    /// (the effective deadline is the smaller of this and the client's
    /// `deadline_ms`). `None` leaves client deadlines as the only cap.
    pub request_timeout: Option<Duration>,
    /// How long a connection may dribble a partial request head before
    /// it is dropped with `408` (the slowloris guard).
    pub header_deadline: Duration,
    /// Socket write timeout: a wedged client cannot pin a worker on a
    /// blocking write forever.
    pub write_timeout: Duration,
    /// `Retry-After` seconds advertised on `429`/`503` shed responses.
    pub retry_after_secs: u64,
    /// Serving-side fault-injection handle (`slow-write`, `conn-reset`,
    /// `worker-panic` sites). Disabled by default.
    pub faults: Faults,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 4,
            max_body_bytes: http::MAX_BODY_BYTES,
            max_connections: 128,
            max_queue: 256,
            token_channel_depth: 64,
            request_timeout: None,
            header_deadline: Duration::from_secs(2),
            write_timeout: Duration::from_secs(10),
            retry_after_secs: 1,
            faults: Faults::disabled(),
        }
    }
}

/// The load-shedding decision for one incoming `/v1/generate`: shed
/// when the combined backlog (jobs parked in the inbox plus rows queued
/// in the scheduler) reaches the queue watermark, or when the KV pool
/// is saturated *and* a backlog exists (admitting more work then only
/// deepens the queue the scheduler is already unable to drain). Pure;
/// mirrored by `python/tests/test_chaos_mirror.py`.
pub fn should_shed(pending: usize, st: &ServerStats, cfg: &ServerConfig) -> bool {
    let backlog = pending + st.queue_depth;
    if backlog >= cfg.max_queue.max(1) {
        return true;
    }
    let bounded = st.token_budget != usize::MAX && st.token_budget > 0;
    bounded && st.resident_tokens >= st.token_budget && backlog > 0
}

/// A decoded `POST /v1/generate` body (the wire-format half of the
/// request; conversion to a [`GenRequest`] happens against the serving
/// session's defaults).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerateRequest {
    /// The prompt to complete (required).
    pub prompt: String,
    /// Adapter the client expects to be served (optional; requests for
    /// any other adapter than the session's are rejected — the decode
    /// graph pins its adapter at construction).
    pub adapter: Option<String>,
    /// Admission class (optional, default `Normal`).
    pub priority: Priority,
    /// Deadline in milliseconds from submission (optional).
    pub deadline_ms: Option<u64>,
    /// Cap on generated tokens (optional; the session default applies).
    pub max_new_tokens: Option<usize>,
    /// Stream tokens as chunked JSON lines instead of one body.
    pub stream: bool,
}

/// Decode and validate a `POST /v1/generate` body. Pure: this is the
/// request-decode half mirrored by the Python wire-format suite.
pub fn decode_generate(body: &[u8]) -> Result<GenerateRequest, JsonError> {
    let doc = super::json::parse(body)?;
    let prompt = doc.req_str("prompt")?.to_string();
    let adapter = doc.opt_str("adapter")?.map(str::to_string);
    let priority = match doc.opt_str("priority")? {
        None => Priority::Normal,
        Some("low") => Priority::Low,
        Some("normal") => Priority::Normal,
        Some("high") => Priority::High,
        Some(_) => {
            return Err(JsonError::TypeError {
                field: "priority".to_string(),
                expected: "one of \"low\"/\"normal\"/\"high\"",
                found: "string",
            })
        }
    };
    let deadline_ms = doc.opt_u64("deadline_ms")?;
    let max_new_tokens = doc.opt_u64("max_new_tokens")?.map(|v| v as usize);
    let stream = doc.opt_bool("stream")?.unwrap_or(false);
    Ok(GenerateRequest {
        prompt,
        adapter,
        priority,
        deadline_ms,
        max_new_tokens,
        stream,
    })
}

/// Wire name of a [`JobOutcome`]. Pure; mirrored.
pub fn outcome_str(outcome: JobOutcome) -> &'static str {
    match outcome {
        JobOutcome::Done => "done",
        JobOutcome::Cancelled => "cancelled",
        JobOutcome::DeadlineExceeded => "deadline_exceeded",
        JobOutcome::TimedOut => "timed_out",
        JobOutcome::Aborted => "aborted",
    }
}

/// The non-streamed `/v1/generate` response body. Pure; mirrored.
pub fn generate_body(outcome: JobOutcome, text: &str) -> JsonValue {
    JsonValue::object([
        ("outcome", JsonValue::s(outcome_str(outcome))),
        ("text", JsonValue::s(text)),
    ])
}

/// One streamed token line (the chunked response is JSON lines: token
/// lines then a final `done` line). Pure; mirrored.
pub fn token_line(text: &str) -> String {
    let mut line =
        JsonValue::object([("token", JsonValue::s(text))]).to_string();
    line.push('\n');
    line
}

/// The final streamed line: the terminal outcome plus the full text
/// (the concatenation of all `token` fields equals `text`). Pure;
/// mirrored.
pub fn done_line(outcome: JobOutcome, text: &str) -> String {
    let mut line = JsonValue::object([
        ("done", JsonValue::b(true)),
        ("outcome", JsonValue::s(outcome_str(outcome))),
        ("text", JsonValue::s(text)),
    ])
    .to_string();
    line.push('\n');
    line
}

/// The `GET /v1/stats` body: scheduler statistics with the KV-block
/// counters nested under `"blocks"`. Pure; mirrored.
pub fn stats_body(st: &ServerStats) -> JsonValue {
    let budget = if st.token_budget == usize::MAX {
        JsonValue::Null // unbounded legacy budget
    } else {
        JsonValue::n(st.token_budget as f64)
    };
    JsonValue::object([
        ("submitted", JsonValue::n(st.submitted as f64)),
        ("completed", JsonValue::n(st.completed as f64)),
        ("cancelled", JsonValue::n(st.cancelled as f64)),
        ("deadline_exceeded", JsonValue::n(st.deadline_exceeded as f64)),
        ("timed_out_jobs", JsonValue::n(st.timed_out_jobs as f64)),
        ("shed_requests", JsonValue::n(st.shed_requests as f64)),
        ("worker_restarts", JsonValue::n(st.worker_restarts as f64)),
        ("preemptions", JsonValue::n(st.preemptions as f64)),
        ("queue_depth", JsonValue::n(st.queue_depth as f64)),
        ("active_rows", JsonValue::n(st.active_rows as f64)),
        ("resident_tokens", JsonValue::n(st.resident_tokens as f64)),
        ("reserved_tokens", JsonValue::n(st.reserved_tokens as f64)),
        ("token_budget", budget),
        ("tokens_generated", JsonValue::n(st.tokens_generated as f64)),
        ("mean_ttft_ms", JsonValue::n(st.mean_ttft_ms())),
        ("tokens_per_sec", JsonValue::n(st.tokens_per_sec())),
        (
            "blocks",
            JsonValue::object([
                ("kv_blocks", JsonValue::n(st.kv_blocks as f64)),
                (
                    "kv_block_tokens",
                    JsonValue::n(st.kv_block_tokens as f64),
                ),
                (
                    "kv_blocks_in_use",
                    JsonValue::n(st.kv_blocks_in_use as f64),
                ),
                (
                    "shared_block_hits",
                    JsonValue::n(st.shared_block_hits as f64),
                ),
                ("cow_forks", JsonValue::n(st.cow_forks as f64)),
                ("swap_outs", JsonValue::n(st.swap_outs as f64)),
            ]),
        ),
    ])
}

/// Concurrently readable [`ServerStats`] snapshot cell: the decode
/// thread publishes a clone after every step, `/v1/stats` workers read
/// whole snapshots under the same lock — no torn reads, ever (the
/// previous stats path handed `ServerStats` to a same-thread callback
/// only; field-by-field publication to atomics would tear).
#[derive(Debug, Default)]
pub struct StatsCell {
    inner: Mutex<ServerStats>,
}

impl StatsCell {
    /// An empty cell (all-zero stats until the first publish).
    pub fn new() -> StatsCell {
        StatsCell::default()
    }

    /// Replace the snapshot (decode thread, once per step).
    pub fn publish(&self, stats: ServerStats) {
        *lock(&self.inner) = stats;
    }

    /// Clone the latest snapshot (any thread).
    pub fn snapshot(&self) -> ServerStats {
        lock(&self.inner).clone()
    }
}

/// Lock a mutex, recovering the data on poisoning (a panicked worker
/// must not wedge every other connection).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Lock-order contract for the serving stack, checked by pallas-lint's
/// `lock-order` pass: `Shared::inbox` and `StatsCell::inner` are both
/// *leaf* locks — a thread holds at most one of them at a time, which
/// rules out lock-cycle deadlocks by construction. Concretely: never
/// call `StatsCell::publish`/`snapshot` (or any other acquiring helper)
/// while an inbox guard is live, and never touch the inbox from inside
/// stats code. If nesting ever becomes necessary, acquire in the order
/// listed here and update this constant plus the lint fixtures.
pub const LOCK_ORDER: &[&str] = &["StatsCell::inner", "Shared::inbox"];

/// One queued generation job: the request plus the bounded channel its
/// events flow back through and the handle that cancels it if the
/// consumer stops draining that channel.
struct Job {
    tag: u64,
    req: GenRequest,
    sink: mpsc::SyncSender<JobEvent>,
    cancel: CancelHandle,
    /// Streaming jobs receive per-token events; non-streaming jobs only
    /// need the terminal event, so the driver skips their tokens and
    /// the channel can never fill from a slow collector.
    stream: bool,
}

/// Events a connection worker receives for its job.
enum JobEvent {
    Rejected(String),
    Token(String),
    Finished { outcome: JobOutcome, text: String },
}

struct Inbox {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// State shared between the decode thread and the connection workers.
struct Shared {
    inbox: Mutex<Inbox>,
    inbox_cv: Condvar,
    stats: StatsCell,
    shutdown: AtomicBool,
    next_tag: AtomicU64,
    /// Requests turned away by overload control (429 watermark, drain
    /// 503, connection cap); merged into published [`ServerStats`].
    shed: AtomicU64,
    /// Connection handlers that panicked and were caught at the worker
    /// boundary; the worker re-entered its accept loop.
    worker_restarts: AtomicU64,
    /// Live connections, against [`ServerConfig::max_connections`].
    connections: AtomicUsize,
    /// session defaults, captured at startup so workers can build
    /// per-request samplers without touching the (!Send) session
    default_sampler: Sampler,
    greedy: bool,
    adapter: String,
}

impl Shared {
    /// The latest published stats with the serving-layer counters
    /// (which live in atomics here, not in the scheduler) merged in.
    fn stats_snapshot(&self) -> ServerStats {
        let mut st = self.stats.snapshot();
        st.shed_requests = self.shed.load(Ordering::SeqCst);
        st.worker_restarts = self.worker_restarts.load(Ordering::SeqCst);
        st
    }
}

/// Per-job sink state held by the decode-thread driver.
struct SinkEntry {
    sink: mpsc::SyncSender<JobEvent>,
    cancel: CancelHandle,
    stream: bool,
    /// Set once a token send found the channel full: the job was
    /// cancelled for backpressure and later tokens are dropped.
    overflowed: bool,
}

/// The inbox-draining [`ServeDriver`] run on the decode thread.
struct EngineDriver<'s> {
    shared: &'s Shared,
    sinks: HashMap<u64, SinkEntry>,
}

impl ServeDriver for EngineDriver<'_> {
    fn poll(&mut self, idle: bool) -> SourcePoll {
        let mut inbox = lock(&self.shared.inbox);
        if idle {
            // nothing queued or running: sleep until a worker submits
            // or the server shuts down (with a timeout backstop so a
            // missed notify can never hang the loop)
            while inbox.jobs.is_empty() && !inbox.closed {
                inbox = match self
                    .shared
                    .inbox_cv
                    .wait_timeout(inbox, Duration::from_millis(50))
                {
                    Ok((guard, _timed_out)) => guard,
                    Err(poisoned) => poisoned.into_inner().0,
                };
            }
        }
        let mut requests = Vec::new();
        while let Some(job) = inbox.jobs.pop_front() {
            self.sinks.insert(
                job.tag,
                SinkEntry {
                    sink: job.sink,
                    cancel: job.cancel,
                    stream: job.stream,
                    overflowed: false,
                },
            );
            requests.push((job.tag, job.req));
        }
        SourcePoll { requests, open: !inbox.closed }
    }

    fn on_event(&mut self, ev: ServeEvent) {
        match ev {
            ServeEvent::Rejected { tag, error } => {
                if let Some(entry) = self.sinks.remove(&tag) {
                    let _ = entry.sink.try_send(JobEvent::Rejected(error));
                }
            }
            ServeEvent::Token { tag, text } => {
                // every send here is try_send: the decode thread must
                // never block on a worker's channel
                if let Some(entry) = self.sinks.get_mut(&tag) {
                    if !entry.stream || entry.overflowed {
                        return; // collectors only need the terminal event
                    }
                    if let Err(mpsc::TrySendError::Full(_)) =
                        entry.sink.try_send(JobEvent::Token(text))
                    {
                        // the consumer stopped draining its own tokens:
                        // backpressure becomes cancellation, not an
                        // unbounded buffer
                        entry.overflowed = true;
                        entry.cancel.cancel();
                    }
                }
            }
            ServeEvent::Finished { tag, outcome, text } => {
                if let Some(entry) = self.sinks.remove(&tag) {
                    // full only for an overflowed (already cancelled)
                    // stream; dropping the sink unblocks its worker
                    // with a disconnect after it drains the buffer
                    let _ = entry
                        .sink
                        .try_send(JobEvent::Finished { outcome, text });
                }
            }
            ServeEvent::Step { mut stats, .. } => {
                stats.shed_requests =
                    self.shared.shed.load(Ordering::SeqCst);
                stats.worker_restarts =
                    self.shared.worker_restarts.load(Ordering::SeqCst);
                self.shared.stats.publish(stats);
            }
        }
    }
}

/// A bound-but-not-yet-serving HTTP server. Binding is split from
/// running so callers (tests, the bench load generator) can read the
/// ephemeral port before the decode loop takes over the thread.
pub struct HttpServer {
    listener: TcpListener,
    cfg: ServerConfig,
}

impl HttpServer {
    /// Bind the listener (non-blocking accept; workers poll it).
    pub fn bind(cfg: ServerConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        Ok(HttpServer { listener, cfg })
    }

    /// The bound address (the real port when `addr` ended in `:0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `POST /v1/shutdown`: workers accept connections on
    /// scoped threads while the calling thread runs the decode loop
    /// (the runtime is single-threaded, so the engine never leaves this
    /// thread). Returns the terminal [`ServeReport`] over every request
    /// served.
    pub fn run(self, session: &mut Session<'_>) -> Result<ServeReport> {
        let shared = Shared {
            inbox: Mutex::new(Inbox { jobs: VecDeque::new(), closed: false }),
            inbox_cv: Condvar::new(),
            stats: StatsCell::new(),
            shutdown: AtomicBool::new(false),
            next_tag: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            connections: AtomicUsize::new(0),
            default_sampler: session.sampler.clone(),
            greedy: session.greedy,
            adapter: session.adapter().to_string(),
        };
        let listener = &self.listener;
        let cfg = &self.cfg;
        std::thread::scope(|scope| {
            for _ in 0..cfg.workers.max(1) {
                let shared = &shared;
                scope.spawn(move || worker_loop(listener, shared, cfg));
            }
            let mut driver =
                EngineDriver { shared: &shared, sinks: HashMap::new() };
            let mut report = session.serve_loop(&mut driver);
            // wake and release every worker, whatever ended the loop
            shared.shutdown.store(true, Ordering::SeqCst);
            lock(&shared.inbox).closed = true;
            shared.inbox_cv.notify_all();
            // fold the serving-layer counters into the terminal report
            if let Ok(rep) = report.as_mut() {
                rep.stats.shed_requests = shared.shed.load(Ordering::SeqCst);
                rep.stats.worker_restarts =
                    shared.worker_restarts.load(Ordering::SeqCst);
            }
            report
        })
    }
}

/// Accept loop: poll the shared non-blocking listener until shutdown.
/// This is the fault-containment boundary: a panic anywhere in a
/// connection handler is caught here, counted as a worker restart, and
/// the worker re-enters the loop — one poisoned request can never take
/// the server down or wedge the inbox (whose lock recovers from
/// poisoning via [`lock`]).
fn worker_loop(listener: &TcpListener, shared: &Shared, cfg: &ServerConfig) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream_pair) => {
                let (mut stream, _) = stream_pair;
                let live =
                    shared.connections.fetch_add(1, Ordering::SeqCst) + 1;
                if live > cfg.max_connections.max(1) {
                    shared.connections.fetch_sub(1, Ordering::SeqCst);
                    shared.shed.fetch_add(1, Ordering::SeqCst);
                    let _ = http::write_error_after(
                        &mut stream,
                        503,
                        "overloaded",
                        "connection limit reached",
                        cfg.retry_after_secs,
                        false,
                    );
                    continue;
                }
                let caught =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || handle_connection(stream, shared, cfg),
                    ));
                shared.connections.fetch_sub(1, Ordering::SeqCst);
                if caught.is_err() {
                    shared.worker_restarts.fetch_add(1, Ordering::SeqCst);
                }
            }
            // no pending connection (or a transient accept error):
            // sleep briefly and re-check the shutdown flag
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Serve one connection through its keep-alive lifetime.
fn handle_connection(stream: TcpStream, shared: &Shared, cfg: &ServerConfig) {
    // injected fault: a panic at the top of the handler, caught (and
    // counted) at the worker boundary — the containment the loopback
    // suite exercises
    if cfg.faults.fire(FaultSite::WorkerPanic) {
        panic!("injected worker panic (fault site worker-panic)");
    }
    // short read timeout: a worker parked on an idle keep-alive
    // connection re-checks the shutdown flag every 100 ms
    if stream.set_read_timeout(Some(Duration::from_millis(100))).is_err() {
        return;
    }
    // bounded writes: a wedged client cannot pin this worker forever
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = RequestReader::new(read_half, cfg.max_body_bytes);
    let mut stream = stream;
    // slowloris guard: when a read times out *with a partial request
    // buffered*, the peer is dribbling bytes — start (or keep) the
    // header-deadline clock; an idle keep-alive connection (no partial
    // data) may park indefinitely
    let mut partial_since: Option<Instant> = None;
    loop {
        match reader.next_request() {
            Err(HttpError::TimedOut) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if reader.has_partial() {
                    let since = *partial_since.get_or_insert_with(Instant::now);
                    if since.elapsed() >= cfg.header_deadline {
                        let _ = http::write_error(
                            &mut stream,
                            408,
                            "timeout",
                            "request header not completed in time",
                            false,
                        );
                        return;
                    }
                } else {
                    partial_since = None;
                }
            }
            Err(HttpError::Closed) | Err(HttpError::Io(_)) => return,
            Err(e) => {
                if let Some(status) = e.status() {
                    let kind = match status {
                        413 => "payload_too_large",
                        _ => "bad_request",
                    };
                    let _ = http::write_error(
                        &mut stream,
                        status,
                        kind,
                        &e.message(),
                        false,
                    );
                }
                return;
            }
            Ok(req) => {
                partial_since = None;
                let keep = req.keep_alive
                    && !shared.shutdown.load(Ordering::SeqCst);
                if !route(&mut stream, &req, keep, shared, cfg) || !keep {
                    return;
                }
            }
        }
    }
}

/// Dispatch one request; returns false when the connection must close.
fn route(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    shared: &Shared,
    cfg: &ServerConfig,
) -> bool {
    // strip any query string before routing
    let path = req.path.split('?').next().unwrap_or_default();
    let known = matches!(
        path,
        "/healthz" | "/v1/stats" | "/v1/generate" | "/v1/shutdown"
    );
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            let body = JsonValue::object([("status", JsonValue::s("ok"))]);
            respond_json(stream, 200, &body, keep)
        }
        ("GET", "/v1/stats") => {
            let body = stats_body(&shared.stats_snapshot());
            respond_json(stream, 200, &body, keep)
        }
        ("POST", "/v1/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            lock(&shared.inbox).closed = true;
            shared.inbox_cv.notify_all();
            let body =
                JsonValue::object([("shutting_down", JsonValue::b(true))]);
            respond_json(stream, 200, &body, false);
            false
        }
        ("POST", "/v1/generate") => {
            handle_generate(stream, req, keep, shared, cfg)
        }
        _ if known => {
            let _ = http::write_error(
                stream,
                405,
                "method_not_allowed",
                &format!("{} is not supported on {}", req.method, path),
                keep,
            );
            true
        }
        _ => {
            let _ = http::write_error(
                stream,
                404,
                "not_found",
                &format!("no such route `{path}`"),
                keep,
            );
            true
        }
    }
}

fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    body: &JsonValue,
    keep: bool,
) -> bool {
    http::write_response(
        stream,
        status,
        "application/json",
        body.to_string().as_bytes(),
        keep,
    )
    .is_ok()
}

/// `POST /v1/generate`: decode, submit to the decode thread, then relay
/// events — one JSON body, or chunked JSON lines when streaming.
/// Overload control happens here: the request is shed with `429` +
/// `Retry-After` when [`should_shed`] says the backlog watermark is
/// crossed, and with a structured `503 {"error":{"kind":"draining"}}`
/// when it arrives during the shutdown drain.
fn handle_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    shared: &Shared,
    cfg: &ServerConfig,
) -> bool {
    let spec = match decode_generate(&req.body) {
        Ok(spec) => spec,
        Err(e) => {
            let _ = http::write_error(
                stream,
                400,
                e.kind(),
                &e.to_string(),
                keep,
            );
            return true;
        }
    };
    // the decode graph pins its adapter literals at construction, so a
    // request for any other adapter cannot be served by this session
    if let Some(name) = &spec.adapter {
        if *name != shared.adapter {
            let _ = http::write_error(
                stream,
                400,
                "unknown_adapter",
                &format!(
                    "this server serves adapter `{}`, not `{name}`",
                    shared.adapter
                ),
                keep,
            );
            return true;
        }
    }
    // build the GenRequest against the session defaults captured at
    // startup; a greedy session stays exactly greedy under a
    // max_new_tokens override (temperature 0.0 is argmax decoding)
    let mut gen = GenRequest::new(spec.prompt.clone())
        .priority(spec.priority);
    // the per-request wall-clock cap maps onto the scheduler deadline:
    // the effective deadline is the tighter of the client's and the
    // server's
    let deadline = match (spec.deadline_ms, cfg.request_timeout) {
        (Some(ms), Some(cap)) => Some(Duration::from_millis(ms).min(cap)),
        (Some(ms), None) => Some(Duration::from_millis(ms)),
        (None, cap) => cap,
    };
    if let Some(d) = deadline {
        gen = gen.deadline(d);
    }
    if let Some(max_new) = spec.max_new_tokens {
        let mut sampler = shared.default_sampler.clone();
        sampler.max_new_tokens = max_new;
        if shared.greedy {
            sampler.temperature = 0.0;
        }
        gen = gen.sampler(sampler);
    }
    let (gen, cancel) = gen.cancellable();
    // bounded per-job event channel: the decode thread try_sends into
    // it and cancels the job if a slow consumer lets it fill
    let (tx, rx) = mpsc::sync_channel(cfg.token_channel_depth.max(1));
    let tag = shared.next_tag.fetch_add(1, Ordering::SeqCst);
    // snapshot the decode-side stats *before* taking the inbox lock:
    // the inbox is a leaf lock (see LOCK_ORDER) and must never nest
    // another acquisition. The snapshot is one publish interval stale
    // at worst; the racy part of the shed decision is the queue depth,
    // which is still read under the inbox lock below.
    let stats_now = shared.stats.snapshot();
    {
        let mut inbox = lock(&shared.inbox);
        if inbox.closed {
            drop(inbox);
            shared.shed.fetch_add(1, Ordering::SeqCst);
            let _ = http::write_error_after(
                stream,
                503,
                "draining",
                "the server is draining and accepts no new work",
                cfg.retry_after_secs,
                false,
            );
            return false;
        }
        // the shed decision runs under the inbox lock so racing
        // workers cannot collectively overshoot the watermark
        if should_shed(inbox.jobs.len(), &stats_now, cfg) {
            drop(inbox);
            shared.shed.fetch_add(1, Ordering::SeqCst);
            let _ = http::write_error_after(
                stream,
                429,
                "overloaded",
                "the queue watermark is crossed; retry shortly",
                cfg.retry_after_secs,
                keep,
            );
            return true;
        }
        inbox.jobs.push_back(Job {
            tag,
            req: gen,
            sink: tx,
            cancel: cancel.clone(),
            stream: spec.stream,
        });
    }
    shared.inbox_cv.notify_all();
    if spec.stream {
        stream_events(stream, &rx, &cancel, &cfg.faults)
    } else {
        collect_events(stream, &rx, keep)
    }
}

/// Non-streamed relay: wait for the terminal event, answer in one body.
fn collect_events(
    stream: &mut TcpStream,
    rx: &mpsc::Receiver<JobEvent>,
    keep: bool,
) -> bool {
    loop {
        match rx.recv() {
            Ok(JobEvent::Token(_)) => {}
            Ok(JobEvent::Finished { outcome, text }) => {
                return respond_json(
                    stream,
                    200,
                    &generate_body(outcome, &text),
                    keep,
                );
            }
            Ok(JobEvent::Rejected(error)) => {
                let _ = http::write_error(
                    stream,
                    400,
                    "invalid_request",
                    &error,
                    keep,
                );
                return true;
            }
            // the decode loop died (its error surfaces from run())
            Err(_) => {
                let _ = http::write_error(
                    stream,
                    500,
                    "engine_stopped",
                    "the decode loop stopped before this job finished",
                    false,
                );
                return false;
            }
        }
    }
}

/// Streamed relay: one chunked JSON line per token, a final `done`
/// line, and — the disconnect→cancel path — any write failure flips the
/// job's [`CancelHandle`] so the scheduler frees the row within a step.
fn stream_events(
    stream: &mut TcpStream,
    rx: &mpsc::Receiver<JobEvent>,
    cancel: &CancelHandle,
    faults: &Faults,
) -> bool {
    let mut writer = match ChunkedWriter::begin(
        stream,
        200,
        "application/jsonl",
        false,
    ) {
        Ok(w) => w,
        Err(_) => {
            cancel.cancel();
            return false;
        }
    };
    loop {
        match rx.recv() {
            Ok(JobEvent::Token(text)) => {
                // injected fault: drop the connection mid-stream, as a
                // flaky network would — must flow through the same
                // disconnect→cancel path as a real write failure
                if faults.fire(FaultSite::ConnReset) {
                    cancel.cancel();
                    while rx.recv().is_ok() {}
                    return false;
                }
                // injected fault: a client draining its stream slowly
                if faults.fire(FaultSite::SlowWrite) {
                    std::thread::sleep(faults.delay());
                }
                if writer.chunk(token_line(&text).as_bytes()).is_err() {
                    // client went away mid-stream: cancel the job and
                    // drain remaining events so nothing leaks
                    cancel.cancel();
                    while rx.recv().is_ok() {}
                    return false;
                }
            }
            Ok(JobEvent::Finished { outcome, text }) => {
                let ok = writer
                    .chunk(done_line(outcome, &text).as_bytes())
                    .is_ok()
                    && writer.finish().is_ok();
                if !ok {
                    cancel.cancel();
                }
                return false; // streamed responses always close
            }
            Ok(JobEvent::Rejected(error)) => {
                let _ = writer.chunk(
                    format!(
                        "{}\n",
                        JsonValue::object([(
                            "error",
                            JsonValue::object([
                                ("kind", JsonValue::s("invalid_request")),
                                ("message", JsonValue::s(error)),
                            ]),
                        )])
                    )
                    .as_bytes(),
                );
                let _ = writer.finish();
                return false;
            }
            Err(_) => {
                cancel.cancel();
                let _ = writer.finish();
                return false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_generate_full_and_minimal() {
        let full = decode_generate(
            br#"{"prompt":"hi","adapter":"base","priority":"high",
                 "deadline_ms":250,"max_new_tokens":8,"stream":true}"#,
        )
        .unwrap();
        assert_eq!(full.prompt, "hi");
        assert_eq!(full.adapter.as_deref(), Some("base"));
        assert_eq!(full.priority, Priority::High);
        assert_eq!(full.deadline_ms, Some(250));
        assert_eq!(full.max_new_tokens, Some(8));
        assert!(full.stream);
        let min = decode_generate(br#"{"prompt":"p"}"#).unwrap();
        assert_eq!(min.priority, Priority::Normal);
        assert_eq!(min.adapter, None);
        assert!(!min.stream);
    }

    #[test]
    fn decode_generate_rejects_bad_bodies() {
        assert_eq!(decode_generate(b"{").unwrap_err().kind(), "parse_error");
        assert_eq!(
            decode_generate(b"{}").unwrap_err().kind(),
            "missing_field"
        );
        assert_eq!(
            decode_generate(br#"{"prompt":7}"#).unwrap_err().kind(),
            "type_error"
        );
        assert_eq!(
            decode_generate(br#"{"prompt":"p","priority":"urgent"}"#)
                .unwrap_err()
                .kind(),
            "type_error"
        );
        assert_eq!(
            decode_generate(br#"{"prompt":"p","max_new_tokens":-1}"#)
                .unwrap_err()
                .kind(),
            "type_error"
        );
    }

    #[test]
    fn response_encoders_are_deterministic() {
        assert_eq!(
            generate_body(JobOutcome::Done, "ab").to_string(),
            r#"{"outcome":"done","text":"ab"}"#
        );
        assert_eq!(token_line("x"), "{\"token\":\"x\"}\n");
        assert_eq!(
            done_line(JobOutcome::Cancelled, "part"),
            "{\"done\":true,\"outcome\":\"cancelled\",\"text\":\"part\"}\n"
        );
        assert_eq!(outcome_str(JobOutcome::TimedOut), "timed_out");
    }

    #[test]
    fn should_shed_watermarks() {
        let cfg = ServerConfig { max_queue: 4, ..Default::default() };
        let mut st = ServerStats::default();
        st.token_budget = usize::MAX; // legacy unbounded budget
        // below the queue watermark: admit
        assert!(!should_shed(0, &st, &cfg));
        assert!(!should_shed(3, &st, &cfg));
        // at the watermark (pending + queued): shed
        assert!(should_shed(4, &st, &cfg));
        st.queue_depth = 2;
        assert!(should_shed(2, &st, &cfg));
        // resident-token pressure only sheds when a backlog exists
        st.queue_depth = 0;
        st.token_budget = 100;
        st.resident_tokens = 100;
        assert!(!should_shed(0, &st, &cfg), "saturated but idle: admit");
        assert!(should_shed(1, &st, &cfg), "saturated with backlog: shed");
        st.resident_tokens = 99;
        assert!(!should_shed(1, &st, &cfg));
    }

    #[test]
    fn stats_body_shape() {
        let mut st = ServerStats { submitted: 3, ..Default::default() };
        st.kv_blocks = 8;
        st.token_budget = usize::MAX;
        st.shed_requests = 2;
        st.worker_restarts = 1;
        st.timed_out_jobs = 4;
        let v = stats_body(&st);
        assert_eq!(v.get("submitted").and_then(JsonValue::as_num), Some(3.0));
        assert_eq!(v.get("token_budget"), Some(&JsonValue::Null));
        assert_eq!(
            v.get("shed_requests").and_then(JsonValue::as_num),
            Some(2.0)
        );
        assert_eq!(
            v.get("worker_restarts").and_then(JsonValue::as_num),
            Some(1.0)
        );
        assert_eq!(
            v.get("timed_out_jobs").and_then(JsonValue::as_num),
            Some(4.0)
        );
        let blocks = v.get("blocks").unwrap();
        assert_eq!(
            blocks.get("kv_blocks").and_then(JsonValue::as_num),
            Some(8.0)
        );
        // the body round-trips through the serve parser
        let back =
            super::super::json::parse(v.to_string().as_bytes()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn stats_cell_is_concurrently_readable() {
        // the satellite-task regression test: readers poll whole
        // snapshots while a producer publishes — no torn reads, and
        // the monotone counters never run backwards
        let cell = StatsCell::new();
        let rounds = 2000u64;
        std::thread::scope(|scope| {
            let producer = &cell;
            scope.spawn(move || {
                for i in 1..=rounds {
                    producer.publish(ServerStats {
                        submitted: i,
                        completed: i,
                        tokens_generated: i * 7,
                        ..Default::default()
                    });
                }
            });
            let mut last = 0u64;
            for _ in 0..rounds {
                let snap = cell.snapshot();
                // a torn read would break the submitted == completed
                // invariant the producer maintains
                assert_eq!(snap.submitted, snap.completed);
                assert_eq!(snap.tokens_generated, snap.submitted * 7);
                assert!(snap.submitted >= last, "counter ran backwards");
                last = snap.submitted;
            }
        });
        assert_eq!(cell.snapshot().submitted, rounds);
    }
}
