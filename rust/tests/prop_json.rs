//! Property tests for the untrusted-input JSON layer
//! (`serve::json`) — no artifacts, no runtime, pure parsing.
//! `python/tests/test_serve_mirror.py` re-runs the same semantics
//! against Python's `json` module, per the repo's cross-language
//! verification discipline.
//!
//! Properties:
//!
//! 1. **canonical round-trip**: for any generated document,
//!    `write(parse(write(v))) == write(v)` — the sorted-key compact
//!    writer is a fixed point of parse∘write;
//! 2. **parse never panics**: on truncations and random byte
//!    mutations of valid documents, and on raw byte soup, `parse`
//!    returns `Ok`/`Err` — it never unwinds (the prop runner would
//!    surface any panic as a failing case);
//! 3. **edge cases pinned**: `1e999` (overflows f64) is rejected,
//!    `-0` keeps its sign through a round-trip, lone UTF-16
//!    surrogates are rejected while proper pairs decode, and the
//!    nesting depth limit admits exactly `max_depth` containers.

use qlora::serve::json::{
    parse, parse_with_limits, JsonValue, MAX_DEPTH,
};
use qlora::util::prop::{check, default_cases};
use qlora::util::rng::Rng;

/// Characters worth stressing in strings: quoting, escapes, raw
/// controls (as already-decoded chars), multi-byte UTF-8, and an
/// astral char (a surrogate pair on the wire in Python).
const STRING_POOL: &[char] = &[
    'a', 'z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t',
    '\u{0008}', '\u{000c}', '\u{0000}', '\u{001f}', 'é', 'ß', '中',
    '\u{2028}', '😀',
];

fn gen_string(rng: &mut Rng) -> String {
    (0..rng.below(12))
        .map(|_| STRING_POOL[rng.below(STRING_POOL.len())])
        .collect()
}

/// Numbers drawn from pools that round-trip exactly through the
/// writer's decimal output: integers, dyadic fractions, powers of
/// ten, and the signed zeros.
fn gen_num(rng: &mut Rng) -> f64 {
    match rng.below(5) {
        0 => rng.below(2_000_001) as f64 - 1_000_000.0,
        1 => (rng.below(4001) as f64 - 2000.0) / 64.0,
        2 => 10f64.powi(rng.below(600) as i32 - 300),
        3 => -0.0,
        _ => 9.007_199_254_740_992e15 * if rng.bool(0.5) { 1.0 } else { -1.0 },
    }
}

fn gen_value(rng: &mut Rng, depth: usize) -> JsonValue {
    let scalar = depth >= 5 || rng.bool(0.4);
    match if scalar { rng.below(4) } else { 4 + rng.below(2) } {
        0 => JsonValue::Null,
        1 => JsonValue::b(rng.bool(0.5)),
        2 => JsonValue::n(gen_num(rng)),
        3 => JsonValue::s(gen_string(rng)),
        4 => JsonValue::array(
            (0..rng.below(5)).map(|_| gen_value(rng, depth + 1)),
        ),
        _ => JsonValue::object(
            (0..rng.below(5))
                .map(|_| (gen_string(rng), gen_value(rng, depth + 1))),
        ),
    }
}

#[test]
fn write_then_parse_is_a_fixed_point() {
    check("json canonical round-trip", default_cases(), |rng| {
        let v = gen_value(rng, 0);
        let first = v.to_string();
        let reparsed = parse(first.as_bytes())
            .unwrap_or_else(|e| panic!("own output rejected: {e}\n{first}"));
        let second = reparsed.to_string();
        assert_eq!(first, second, "writer is not a parse fixed point");
    });
}

#[test]
fn parse_never_panics_on_mutated_documents() {
    check("json mutation fuzz", default_cases(), |rng| {
        let mut bytes = gen_value(rng, 0).to_string().into_bytes();
        for _ in 0..1 + rng.below(6) {
            match rng.below(3) {
                0 if !bytes.is_empty() => {
                    let i = rng.below(bytes.len());
                    bytes[i] = rng.below(256) as u8;
                }
                1 => bytes.truncate(rng.below(bytes.len() + 1)),
                _ => {
                    let i = rng.below(bytes.len() + 1);
                    bytes.insert(i, rng.below(256) as u8);
                }
            }
        }
        // must return, never unwind; the result itself is unspecified
        let _ = parse(&bytes);
    });
}

#[test]
fn parse_never_panics_on_byte_soup() {
    check("json byte-soup fuzz", default_cases(), |rng| {
        let bytes: Vec<u8> =
            (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
        let _ = parse(&bytes);
        // a biased soup of structural bytes digs deeper into the
        // parser than uniform noise does
        let structural = b"[]{}\",:\\u0 .-e1tfn";
        let biased: Vec<u8> = (0..rng.below(64))
            .map(|_| structural[rng.below(structural.len())])
            .collect();
        let _ = parse(&biased);
    });
}

#[test]
fn overflowing_exponent_is_rejected() {
    // pinned divergence from Python's json, which parses 1e999 as inf
    for doc in ["1e999", "-1e999", "[1e999]", "1e99999999"] {
        assert!(parse(doc.as_bytes()).is_err(), "{doc} must be rejected");
    }
    // ...but the largest finite double is fine
    assert!(parse(b"1.7976931348623157e308").is_ok());
}

#[test]
fn negative_zero_keeps_its_sign() {
    for doc in ["-0", "-0.0", "-0e5"] {
        let v = parse(doc.as_bytes()).unwrap();
        let n = v.as_num().unwrap();
        assert_eq!(n, 0.0);
        assert!(n.is_sign_negative(), "{doc} lost its sign");
        assert_eq!(v.to_string(), "-0", "{doc} must write back as -0");
    }
    assert_eq!(parse(b"0").unwrap().to_string(), "0");
}

#[test]
fn lone_surrogates_are_rejected_and_pairs_decode() {
    // pinned divergence from Python's json, which produces an
    // unpaired UTF-16 code unit for these
    for doc in
        [r#""\ud800""#, r#""\udc00""#, r#""\ud800x""#, r#""\ud800\ud800""#]
    {
        assert!(parse(doc.as_bytes()).is_err(), "{doc} must be rejected");
    }
    let v = parse(br#""😀""#).unwrap();
    assert_eq!(v.as_str(), Some("😀"));
}

#[test]
fn depth_limit_admits_exactly_max_depth_containers() {
    let nested = |n: usize| {
        let mut s = String::new();
        for _ in 0..n {
            s.push('[');
        }
        s.push('1');
        for _ in 0..n {
            s.push(']');
        }
        s.into_bytes()
    };
    assert!(parse(&nested(MAX_DEPTH)).is_ok());
    assert!(parse(&nested(MAX_DEPTH + 1)).is_err());
    // the same boundary under a custom limit, with objects mixed in
    assert!(parse_with_limits(&nested(4), 4, 1 << 20).is_ok());
    assert!(parse_with_limits(&nested(5), 4, 1 << 20).is_err());
    assert!(parse_with_limits(br#"{"a":[{"b":1}]}"#, 3, 1 << 20).is_ok());
    assert!(parse_with_limits(br#"{"a":[{"b":[]}]}"#, 3, 1 << 20).is_err());
    // scalars inside the deepest admitted container are fine
    assert!(parse_with_limits(b"[[1,true,\"x\"]]", 2, 1 << 20).is_ok());
}

#[test]
fn size_limit_is_enforced() {
    let doc = vec![b' '; 32];
    assert!(parse_with_limits(&doc, MAX_DEPTH, 16).is_err());
    assert!(parse_with_limits(b"1", MAX_DEPTH, 16).is_ok());
}
