//! Extra cross-implementation property test: the integer fast path must
//! agree exactly with the generic midpoint-search encoder.

use qlora::quant::codebook::{Codebook, DType};
use qlora::quant::quantize_blockwise;
use qlora::util::prop::{self, gen};

#[test]
fn int_fast_path_matches_midpoint_search() {
    for dt in [DType::Int4, DType::Int8] {
        let cb = Codebook::new(dt);
        prop::check(&format!("int-fastpath-{:?}", dt), 48, |rng| {
            let n = gen::blocked_len(rng, 64, 8);
            let x = gen::outlier_vec(rng, n, 0.05, 8.0);
            let (fast, _) = quantize_blockwise(&x, &cb, 64).unwrap();
            // reference: generic encoder
            let mut slow = vec![0u8; n];
            for b in 0..n / 64 {
                let chunk = &x[b * 64..(b + 1) * 64];
                let am = chunk.iter().fold(0f32, |a, v| a.max(v.abs()));
                let s = if am > 0.0 { am } else { 1.0 };
                for (i, &v) in chunk.iter().enumerate() {
                    slow[b * 64 + i] = cb.encode(v / s);
                }
            }
            assert_eq!(fast, slow);
        });
    }
}
