//! Chaos property suite: randomized, *seeded* fault schedules over the
//! serving scheduler — the runnable half of the robustness plane (the
//! HTTP-layer sites are exercised by `serve_http.rs`; this suite needs
//! no artifacts and runs everywhere). Mirrored line-for-line by
//! `python/tests/test_chaos_mirror.py`.
//!
//! Each schedule drives the serve loop shape (poll → admit → swap-outs
//! → step) with a fabricated clock while faults fire underneath:
//! injected KV block-allocation failures (the `block-alloc` site),
//! client cancellations, tight deadlines, stalled rows against the
//! decode-step watchdog, and a shutdown drain that closes the arrival
//! stream mid-run. Under **every** schedule:
//!
//! 1. every submitted request reaches **exactly one** terminal
//!    [`JobOutcome`] — no silent drops, no double completions;
//! 2. the loop never deadlocks or livelocks (a hard step bound — fault
//!    caps guarantee injected pressure dries up);
//! 3. [`BlockManager::check_invariants`] holds after every step — no
//!    leaked, double-freed, or miscounted KV block, ever;
//! 4. the drain completes: once arrivals stop, the scheduler reaches
//!    `finished()` and returns a result for everything admitted.

use std::time::{Duration, Instant};

use qlora::engine::scheduler::{JobOutcome, Priority, Request, Scheduler};
use qlora::engine::CancelHandle;
use qlora::paged::BlockConfig;
use qlora::util::faults::{FaultPlan, FaultSite, Faults};
use qlora::util::rng::Rng;

/// Everything the harness remembers about one request in the schedule.
struct Spec {
    arrive_at: usize,
    cancel_at: Option<usize>,
    has_deadline: bool,
    /// From this step on the job's row is never pushed — a hung decode
    /// step; only assigned when the watchdog is armed to retire it.
    stall_at: Option<usize>,
    handle: CancelHandle,
    prompt_len: usize,
    max_new: usize,
}

fn random_priority(rng: &mut Rng) -> Priority {
    match rng.below(3) {
        0 => Priority::Low,
        1 => Priority::Normal,
        _ => Priority::High,
    }
}

/// One seeded chaos schedule; panics iff a robustness invariant breaks.
fn run_chaos_case(seed: u64) {
    let mut rng = Rng::new(seed);
    let capacity = 1 + rng.below(4);
    let seq_len = 8 + rng.below(16); // 8..24
    let block_tokens = 2 + rng.below(4); // 2..6
    let per_row = seq_len.div_ceil(block_tokens);
    // roomy enough that nothing aborts for sheer size — pressure comes
    // from co-residents and the injected allocation failures
    let n_blocks = per_row * (capacity + 1);
    let n_jobs = 1 + rng.below(10);

    // every schedule arms block-alloc (capped so it dries up); the
    // plan seed is drawn from the case RNG, so schedules differ in
    // *where* faults land, not just in how the jobs look
    let plan = FaultPlan { seed: rng.next_u64(), ..FaultPlan::default() }
        .with(
            FaultSite::BlockAlloc,
            0.6 * rng.f64(),
            Some(rng.below(24) as u64),
        );
    let mut sched = Scheduler::with_blocks(
        capacity,
        BlockConfig::new(block_tokens, n_blocks),
    )
    .unwrap();
    sched.set_faults(Faults::new(&plan));
    let watchdog = rng.below(2) == 0;
    if watchdog {
        sched.set_watchdog(Some(Duration::from_millis(
            30 + rng.below(50) as u64,
        )));
    }

    // arrivals trickle in until the shutdown drain closes the stream;
    // requests scheduled to arrive later are never submitted (the HTTP
    // layer sheds those with a draining 503 before they reach us)
    let drain_at = 4 + rng.below(20);
    let mut specs: Vec<Spec> = Vec::new();
    for _ in 0..n_jobs {
        let prompt_len = 1 + rng.below(seq_len / 2);
        specs.push(Spec {
            arrive_at: rng.below(24),
            cancel_at: (rng.below(4) == 0).then(|| rng.below(40)),
            has_deadline: rng.below(4) == 0,
            stall_at: (watchdog && rng.below(5) == 0)
                .then(|| rng.below(30)),
            handle: CancelHandle::new(),
            prompt_len,
            max_new: rng.below(seq_len - prompt_len + 1),
        });
    }

    let mut now = Instant::now();
    let mut step = 0usize;
    let mut submitted = vec![false; n_jobs];
    let mut spec_of_job: Vec<usize> = Vec::new();
    loop {
        let no_more_arrivals = step >= drain_at
            || specs
                .iter()
                .enumerate()
                .all(|(i, s)| submitted[i] || s.arrive_at < step);
        if no_more_arrivals && sched.finished() {
            break; // the drain completed (invariant 4)
        }
        // invariant 2: no deadlock/livelock under any schedule
        assert!(step < 10_000, "chaos case {seed}: drain never completed");
        now += Duration::from_millis(1 + rng.below(4) as u64);

        if step < drain_at {
            for (i, spec) in specs.iter().enumerate() {
                if spec.arrive_at == step && !submitted[i] {
                    let mut req =
                        Request::new(vec![0; spec.prompt_len], spec.max_new)
                            .priority(random_priority(&mut rng));
                    if spec.has_deadline {
                        req = req.deadline(Duration::from_millis(
                            10 + rng.below(80) as u64,
                        ));
                    }
                    let (jid, _) = sched.submit_with_handle(
                        req,
                        spec.handle.clone(),
                        now,
                    );
                    assert_eq!(jid, spec_of_job.len());
                    spec_of_job.push(i);
                    submitted[i] = true;
                }
            }
        }
        for (i, spec) in specs.iter().enumerate() {
            if submitted[i] && spec.cancel_at == Some(step) {
                spec.handle.cancel();
            }
        }

        // --- the serve loop, verbatim ---
        sched.poll(now);
        sched.admit(now);
        sched.take_swap_outs();
        for row in sched.active_rows() {
            if sched.budget_exhausted(row, seq_len) {
                sched.retire(row).unwrap();
            }
        }
        for row in sched.active_rows() {
            // an earlier push this step may have swapped this row out
            let Some(id) = sched.job_in(row) else { continue };
            let spec = &specs[spec_of_job[id]];
            if spec.stall_at.is_some_and(|s| step >= s) {
                // a hung decode step: record nothing for this row, ever
                // again — the armed watchdog must retire it
            } else if rng.below(8) == 0 {
                sched.retire(row).unwrap(); // "EOS"
            } else {
                // stamp every token with its job id (invariant 1)
                sched.push(row, 1000 + id as i32, now).unwrap();
            }
        }
        sched.take_swap_outs();
        // invariant 3: block-pool consistency after every single step
        sched.check_block_invariants();
        step += 1;
    }

    let results = sched.take_results();
    let n_submitted = submitted.iter().filter(|&&s| s).count();
    // invariant 1: exactly one terminal outcome per submitted request
    assert_eq!(
        results.len(),
        n_submitted,
        "chaos case {seed}: outcome count mismatch"
    );
    for (id, r) in results.iter().enumerate() {
        assert!(
            r.tokens.iter().all(|&t| t == 1000 + id as i32),
            "chaos case {seed}: job {id} holds foreign tokens {:?}",
            r.tokens
        );
        let spec = &specs[spec_of_job[id]];
        assert!(
            r.tokens.len() <= spec.max_new,
            "chaos case {seed}: job {id} overran max_new"
        );
        assert_ne!(
            r.outcome,
            JobOutcome::Aborted,
            "chaos case {seed}: faults must degrade, never abort"
        );
        // a job nobody interfered with ends Done; a stalled job is
        // either Done (it finished before its hang began) or retired
        // TimedOut by the watchdog — never stuck, never anything else
        if spec.cancel_at.is_none() && !spec.has_deadline {
            if spec.stall_at.is_none() {
                assert_eq!(
                    r.outcome,
                    JobOutcome::Done,
                    "chaos case {seed}: undisturbed job {id} must end Done"
                );
            } else {
                assert!(
                    matches!(
                        r.outcome,
                        JobOutcome::Done | JobOutcome::TimedOut
                    ),
                    "chaos case {seed}: stalled job {id} ended {:?}",
                    r.outcome
                );
            }
        }
    }
}

#[test]
fn chaos_schedules_preserve_serving_invariants() {
    // ≥300 distinct seeded schedules, mirrored seed-for-seed in
    // python/tests/test_chaos_mirror.py
    for case in 0..300u64 {
        run_chaos_case(0xC4A05 ^ case);
    }
}

#[test]
fn watchdog_drains_a_fully_stalled_schedule() {
    // the pathological schedule: every step stalls (nothing is ever
    // pushed); without the watchdog this would spin at the step bound,
    // with it every job is retired TimedOut and the drain completes
    let mut sched = Scheduler::with_blocks(2, BlockConfig::new(4, 16)).unwrap();
    sched.set_watchdog(Some(Duration::from_millis(40)));
    let mut now = Instant::now();
    for _ in 0..4 {
        sched.submit(Request::new(vec![0; 3], 8), now);
    }
    let mut steps = 0;
    while !sched.finished() {
        assert!(steps < 1_000, "watchdog never drained the stall");
        now += Duration::from_millis(10);
        sched.poll(now);
        sched.admit(now);
        sched.take_swap_outs();
        sched.check_block_invariants();
        steps += 1;
    }
    let results = sched.take_results();
    assert_eq!(results.len(), 4);
    for r in &results {
        assert_eq!(r.outcome, JobOutcome::TimedOut);
        assert!(r.tokens.is_empty());
    }
    assert_eq!(sched.stats().timed_out_jobs, 4);
}
