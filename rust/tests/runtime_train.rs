//! Integration tests over the PJRT runtime: artifact loading, the
//! training loop, checkpoint round-trips, eval, and generation. These are
//! the L3 counterparts of the paper's section 4 claims at reproduction
//! scale. Skip (with a message) when artifacts are not built.

use qlora::coordinator::checkpoint;
use qlora::coordinator::generate::Sampler;
use qlora::coordinator::trainer::{TrainOptions, Trainer};
use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use qlora::data::tokenizer::Tokenizer;
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::util::rng::Rng;

// PjRtClient is single-threaded (Rc internally), so each test builds its
// own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Runtime, Manifest)> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir).ok()?;
    let rt = Runtime::cpu().ok()?;
    Some((rt, manifest))
}

fn batcher_for(trainer: &Trainer, n: usize, seed: u64) -> Batcher {
    let cfg = &trainer.spec.cfg;
    let ds = corpus(CorpusKind::Alpaca, n, seed);
    Batcher::new(&ds, Tokenizer::new(cfg.vocab), cfg.batch, cfg.seq_len,
                 false)
}

#[test]
fn train_step_reduces_loss() {
    let Some((rt, manifest)) = env() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let mut trainer = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let batcher = batcher_for(&trainer, 64, 1);
    let batch = &batcher.epoch(0)[0];
    // overfit a single batch: loss must drop substantially
    let first = trainer.step(batch).unwrap();
    let mut last = first;
    for _ in 0..40 {
        last = trainer.step(batch).unwrap();
    }
    assert!(last < first - 0.3, "loss {first} -> {last}");
    assert!(last.is_finite());
}

#[test]
fn eval_is_pure() {
    let Some((rt, manifest)) = env() else { return };
    let trainer = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let batcher = batcher_for(&trainer, 32, 2);
    let batch = &batcher.epoch(0)[0];
    let (l1, a1) = trainer.eval(batch).unwrap();
    let (l2, a2) = trainer.eval(batch).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    assert!((0.0..=1.0).contains(&a1));
}

#[test]
fn full_finetune_artifact_trains() {
    let Some((rt, manifest)) = env() else { return };
    let mut trainer = Trainer::new(&rt, &manifest, "tiny_fullft").unwrap();
    assert_eq!(trainer.spec.n_frozen, 0, "full FT has no frozen tensors");
    let batcher = batcher_for(&trainer, 32, 3);
    let batch = &batcher.epoch(0)[0];
    let first = trainer.step(batch).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = trainer.step(batch).unwrap();
    }
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some((rt, manifest)) = env() else { return };
    let mut trainer = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let batcher = batcher_for(&trainer, 32, 4);
    let batch = &batcher.epoch(0)[0];
    for _ in 0..5 {
        trainer.step(batch).unwrap();
    }
    let (l_before, _) = trainer.eval(batch).unwrap();
    let path = std::env::temp_dir().join("qlora_ckpt_test.tensors");
    checkpoint::save(&trainer, &path).unwrap();

    // fresh trainer diverges from the trained one…
    let mut fresh = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let (l_fresh, _) = fresh.eval(batch).unwrap();
    assert_ne!(l_before, l_fresh);
    // …until the checkpoint is restored
    checkpoint::load(&mut fresh, &path).unwrap();
    let (l_after, _) = fresh.eval(batch).unwrap();
    assert_eq!(l_before, l_after);
}

#[test]
fn adapters_checkpoint_is_small() {
    let Some((rt, manifest)) = env() else { return };
    let trainer = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let full = std::env::temp_dir().join("qlora_full_test.tensors");
    let adapters = std::env::temp_dir().join("qlora_adapters_test.tensors");
    checkpoint::save(&trainer, &full).unwrap();
    checkpoint::save_adapters(&trainer, &adapters).unwrap();
    let fs = std::fs::metadata(&full).unwrap().len();
    let as_ = std::fs::metadata(&adapters).unwrap().len();
    // adapters ≈ 1/3 of (adapters + m + v) + step
    assert!(as_ * 2 < fs, "adapters {as_} vs full {fs}");
}

#[test]
fn train_loop_with_pager_and_log() {
    let Some((rt, manifest)) = env() else { return };
    let mut trainer = Trainer::new(&rt, &manifest, "tiny_scope_all").unwrap();
    let batcher = batcher_for(&trainer, 64, 5);
    let eval_ds = eval_set(EvalSuite::VicunaProxy,
                           trainer.spec.cfg.batch * 2, 6);
    let eval_b = Batcher::new(&eval_ds, Tokenizer::new(trainer.spec.cfg.vocab),
                              trainer.spec.cfg.batch, trainer.spec.cfg.seq_len,
                              false);
    let opts = TrainOptions {
        steps: 12,
        eval_every: 6,
        seed: 1,
        paged: true,
        device_budget: 8 << 20,
    };
    let log = trainer.train(&batcher, Some(&eval_b), &opts).unwrap();
    assert_eq!(log.losses.len(), 12);
    assert_eq!(log.evals.len(), 2);
    assert!(log.pager_stats.is_some());
    assert!(log.mean_step_time().as_micros() > 0);
}

#[test]
fn generation_produces_tokens() {
    let Some((rt, manifest)) = env() else { return };
    let trainer = Trainer::new(&rt, &manifest, "e2e").unwrap();
    let tok = Tokenizer::new(trainer.spec.cfg.vocab);
    let sampler = Sampler { top_p: 0.9, temperature: 0.7, max_new_tokens: 8 };
    let mut rng = Rng::new(1);
    let out = sampler.generate(&trainer, &tok, "copy ab", &mut rng, false)
        .unwrap();
    // untrained model: content arbitrary, machinery must work
    assert!(out.len() <= 64);
}

#[test]
fn quantized_artifacts_have_u8_frozen_tensors() {
    let Some((_rt, manifest)) = env() else { return };
    let spec = manifest.get("tiny_scope_all").unwrap();
    assert!(spec.frozen_sig.iter().any(|t| t.dtype == "u8"),
            "NF4 base must ship packed u8 codes");
    // and the 16-bit variant must not
    let spec16 = manifest.get("tiny_lora16").unwrap();
    assert!(spec16.frozen_sig.iter().all(|t| t.dtype != "u8"));
}

#[test]
fn frozen_base_is_smaller_when_quantized() {
    let Some((_rt, manifest)) = env() else { return };
    let bytes = |name: &str| -> usize {
        manifest
            .get(name)
            .unwrap()
            .frozen_sig
            .iter()
            .map(|t| t.elems() * if t.dtype == "u8" { 1 } else { 4 })
            .sum()
    };
    let q = bytes("tiny_scope_all");
    let f = bytes("tiny_lora16");
    assert!(q * 2 < f, "quantized frozen {q} vs 16-bit {f}");
}
