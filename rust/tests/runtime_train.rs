//! Integration tests over the PJRT runtime: artifact loading, the
//! training loop as an engine client, checkpoint round-trips, eval, and
//! engine/session generation with hot-swapped adapters. These are the L3
//! counterparts of the paper's section 4 claims at reproduction scale.
//! Each test skips with a message when artifacts are not built, so
//! `cargo test -q` is green from a fresh clone.

use std::rc::Rc;

use qlora::coordinator::checkpoint;
use qlora::coordinator::trainer::{TrainOptions, Trainer};
use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use qlora::data::tokenizer::Tokenizer;
use qlora::engine::{Engine, Sampler, BASE_ADAPTER};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;

// PjRtClient is single-threaded (Rc internally), so each test builds its
// own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the runtime tests"
        );
        return None;
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipped: PJRT CPU runtime unavailable: {e:#}");
            return None;
        }
    };
    Some((Rc::new(rt), manifest))
}

fn engine(rt: &Rc<Runtime>, manifest: &Manifest, name: &str) -> Engine {
    Engine::new(rt.clone(), manifest, name).unwrap()
}

fn batcher_for(engine: &Engine, n: usize, seed: u64) -> Batcher {
    let cfg = &engine.spec.cfg;
    let ds = corpus(CorpusKind::Alpaca, n, seed);
    Batcher::new(&ds, Tokenizer::new(cfg.vocab), cfg.batch, cfg.seq_len,
                 false)
}

#[test]
fn train_step_reduces_loss() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_scope_all");
    let mut trainer = Trainer::new(&eng).unwrap();
    let batcher = batcher_for(&eng, 64, 1);
    let batch = &batcher.epoch(0)[0];
    // overfit a single batch: loss must drop substantially
    let first = trainer.step(batch).unwrap();
    let mut last = first;
    for _ in 0..40 {
        last = trainer.step(batch).unwrap();
    }
    assert!(last < first - 0.3, "loss {first} -> {last}");
    assert!(last.is_finite());
}

#[test]
fn session_eval_is_pure_and_matches_fresh_trainer() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_scope_all");
    let batcher = batcher_for(&eng, 32, 2);
    let batch = &batcher.epoch(0)[0];
    // eval through the serving session (base adapter, no trainer at all)
    let session = eng.session().build().unwrap();
    let (l1, a1) = session.eval(batch).unwrap();
    let (l2, a2) = session.eval(batch).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(a1, a2);
    assert!((0.0..=1.0).contains(&a1));
    // a fresh trainer evaluates the same state to the same numbers
    let trainer = Trainer::new(&eng).unwrap();
    let (lt, at) = trainer.eval(batch).unwrap();
    assert_eq!(l1, lt);
    assert_eq!(a1, at);
}

#[test]
fn full_finetune_artifact_trains() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_fullft");
    assert_eq!(eng.spec.n_frozen, 0, "full FT has no frozen tensors");
    let mut trainer = Trainer::new(&eng).unwrap();
    let batcher = batcher_for(&eng, 32, 3);
    let batch = &batcher.epoch(0)[0];
    let first = trainer.step(batch).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = trainer.step(batch).unwrap();
    }
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_scope_all");
    let mut trainer = Trainer::new(&eng).unwrap();
    let batcher = batcher_for(&eng, 32, 4);
    let batch = &batcher.epoch(0)[0];
    for _ in 0..5 {
        trainer.step(batch).unwrap();
    }
    let (l_before, _) = trainer.eval(batch).unwrap();
    let path = std::env::temp_dir().join("qlora_ckpt_test.tensors");
    checkpoint::save(&trainer, &path).unwrap();

    // fresh trainer diverges from the trained one…
    let mut fresh = Trainer::new(&eng).unwrap();
    let (l_fresh, _) = fresh.eval(batch).unwrap();
    assert_ne!(l_before, l_fresh);
    // …until the checkpoint is restored
    checkpoint::load(&mut fresh, &path).unwrap();
    let (l_after, _) = fresh.eval(batch).unwrap();
    assert_eq!(l_before, l_after);
}

#[test]
fn adapters_checkpoint_is_small() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_scope_all");
    let trainer = Trainer::new(&eng).unwrap();
    let full = std::env::temp_dir().join("qlora_full_test.tensors");
    let adapters = std::env::temp_dir().join("qlora_adapters_test.tensors");
    checkpoint::save(&trainer, &full).unwrap();
    checkpoint::save_adapters(&trainer, &adapters).unwrap();
    let fs = std::fs::metadata(&full).unwrap().len();
    let as_ = std::fs::metadata(&adapters).unwrap().len();
    // adapters ≈ 1/3 of (adapters + m + v) + step
    assert!(as_ * 2 < fs, "adapters {as_} vs full {fs}");
}

#[test]
fn train_loop_with_pager_and_log() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "tiny_scope_all");
    let mut trainer = Trainer::new(&eng).unwrap();
    let batcher = batcher_for(&eng, 64, 5);
    let cfg = &eng.spec.cfg;
    let eval_ds = eval_set(EvalSuite::VicunaProxy, cfg.batch * 2, 6);
    let eval_b = Batcher::new(&eval_ds, Tokenizer::new(cfg.vocab),
                              cfg.batch, cfg.seq_len, false);
    let opts = TrainOptions {
        steps: 12,
        eval_every: 6,
        seed: 1,
        paged: true,
        device_budget: 8 << 20,
    };
    let log = trainer.train(&batcher, Some(&eval_b), &opts).unwrap();
    assert_eq!(log.losses.len(), 12);
    assert_eq!(log.evals.len(), 2);
    assert!(log.pager_stats.is_some());
    assert!(log.mean_step_time().as_micros() > 0);
}

#[test]
fn session_generation_produces_tokens() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let mut session =
        eng.session().sampler(sampler).seed(1).build().unwrap();
    // untrained model: content arbitrary, machinery must work
    let out = session.generate("copy ab").unwrap();
    assert!(out.len() <= 64);
    assert!(session.tokens_generated() <= 8);
}

#[test]
fn streaming_matches_whole_generation() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    // same seed ⇒ the streamed pieces concatenate to the same completion
    // the *batched* decode loop produces — an independent implementation,
    // so a bug in either loop breaks the equality
    let mut s1 =
        eng.session().sampler(sampler.clone()).seed(42).build().unwrap();
    let whole = s1.generate_batch(&["rev abc"]).unwrap().remove(0);
    let mut s2 =
        eng.session().sampler(sampler).seed(42).build().unwrap();
    let mut streamed = String::new();
    let mut pieces = 0;
    let mut stream = s2.stream("rev abc").unwrap();
    while let Some(piece) = stream.next_token_text() {
        streamed.push_str(&piece.unwrap());
        pieces += 1;
    }
    assert_eq!(whole, streamed);
    assert!(pieces <= 6);
}

#[test]
fn batched_decoding_matches_single_greedy() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    let mut session =
        eng.session().sampler(sampler).greedy(true).build().unwrap();
    let prompts = ["copy ab", "rev abcd"];
    let batched = session.generate_batch(&prompts).unwrap();
    assert_eq!(batched.len(), 2);
    // greedy decoding is sampling-free, so each batched row must equal
    // the prompt decoded alone (validates the per-row logits offsets)
    for (p, b) in prompts.iter().zip(batched.iter()) {
        let single = session.generate(p).unwrap();
        assert_eq!(&single, b, "row for {p:?} diverged");
    }
}

#[test]
fn two_adapters_share_one_frozen_base() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    // train briefly and publish the result as a second adapter
    let mut trainer = Trainer::new(&eng).unwrap();
    let batcher = batcher_for(&eng, 64, 7);
    let batch = &batcher.epoch(0)[0];
    let first = trainer.step(batch).unwrap();
    let mut last = first;
    for _ in 0..30 {
        last = trainer.step(batch).unwrap();
    }
    assert!(last < first, "training went nowhere: {first} -> {last}");
    trainer.publish_adapter("tuned").unwrap();
    assert_eq!(eng.adapter_names(), vec!["base".to_string(),
                                         "tuned".to_string()]);

    // same prompts, same engine, no base re-upload: the two adapters must
    // produce different greedy completions somewhere
    let prompts = ["copy ab", "rev abcd", "up hi"];
    let mut base =
        eng.session().adapter(BASE_ADAPTER).greedy(true).build().unwrap();
    let mut tuned =
        eng.session().adapter("tuned").greedy(true).build().unwrap();
    let mut differed = false;
    for p in prompts {
        if base.generate(p).unwrap() != tuned.generate(p).unwrap() {
            differed = true;
        }
    }
    assert!(differed, "30 overfit steps changed no greedy completion");

    // hot-swap within one session: switching adapter changes the output
    // deterministically back and forth
    let mut s = eng.session().greedy(true).build().unwrap();
    let b0 = s.generate("copy ab").unwrap();
    s.set_adapter("tuned").unwrap();
    let t0 = s.generate("copy ab").unwrap();
    s.set_adapter(BASE_ADAPTER).unwrap();
    assert_eq!(s.generate("copy ab").unwrap(), b0);
    let _ = t0;
}

#[test]
fn missing_adapter_is_a_clear_error() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    let err = match eng.session().adapter("nope").build() {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("building a session over a missing adapter"),
    };
    assert!(err.contains("nope") && err.contains("base"), "{err}");
}

#[test]
fn quantized_artifacts_have_u8_frozen_tensors() {
    let Some((_rt, manifest)) = env() else { return };
    let spec = manifest.get("tiny_scope_all").unwrap();
    assert!(spec.frozen_sig.iter().any(|t| t.dtype == "u8"),
            "NF4 base must ship packed u8 codes");
    // and the 16-bit variant must not
    let spec16 = manifest.get("tiny_lora16").unwrap();
    assert!(spec16.frozen_sig.iter().all(|t| t.dtype != "u8"));
}

#[test]
fn frozen_base_is_smaller_when_quantized() {
    let Some((_rt, manifest)) = env() else { return };
    let bytes = |name: &str| -> usize {
        manifest
            .get(name)
            .unwrap()
            .frozen_sig
            .iter()
            .map(|t| t.elems() * if t.dtype == "u8" { 1 } else { 4 })
            .sum()
    };
    let q = bytes("tiny_scope_all");
    let f = bytes("tiny_lora16");
    assert!(q * 2 < f, "quantized frozen {q} vs 16-bit {f}");
}

#[test]
fn arena_ranks_real_adapters() {
    let Some((rt, manifest)) = env() else { return };
    let eng = engine(&rt, &manifest, "e2e");
    // a clone of the base adapter under a second name: identical
    // completions, so the tournament must converge to (noisy) ties
    let twin = eng.adapter_tensors(BASE_ADAPTER).unwrap();
    eng.register_adapter("twin", twin).unwrap();
    let judge = qlora::eval::Judge::gpt4();
    let report = qlora::eval::arena::run_arena(
        &eng,
        &["base", "twin"],
        EvalSuite::VicunaProxy,
        2,
        &judge,
        50,
        3,
    )
    .unwrap();
    assert_eq!(report.adapters.len(), 2);
    assert_eq!(report.summaries.len(), 2);
    assert!(report.table().contains("adapter arena"));
}
