//! End-to-end HTTP serving over real artifacts and a real loopback
//! TCP socket: bit-identity between `POST /v1/generate`, its streamed
//! variant, and `Session::serve`; the structured-JSON error contract;
//! live `/v1/stats` polling; and the disconnect→cancel path. Each test
//! skips with a message when artifacts are not built (the wire-format
//! functions themselves are covered without artifacts by the
//! `serve::server` unit tests and `python/tests/test_serve_mirror.py`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::rc::Rc;
use std::time::{Duration, Instant};

use qlora::engine::{Engine, GenRequest, JobOutcome, Sampler};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::serve::json::{parse, JsonValue};
use qlora::serve::{HttpServer, ServerConfig};

// PjRtClient is single-threaded (Rc internally), so each test builds
// its own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the HTTP serving tests"
        );
        return None;
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipped: PJRT CPU runtime unavailable: {e:#}");
            return None;
        }
    };
    Some((Rc::new(rt), manifest))
}

fn engine(rt: &Rc<Runtime>, manifest: &Manifest) -> Option<Engine> {
    match Engine::new(rt.clone(), manifest, "e2e") {
        Ok(eng) => Some(eng),
        Err(e) => {
            eprintln!("skipped: artifact \"e2e\" unavailable: {e:#}");
            None
        }
    }
}

// ------------------------------------------------------- tiny client

/// One `Connection: close` request; returns (status, headers, body).
/// The server closes after every such exchange, so reading to EOF is
/// the framing.
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("read timeout");
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n"
    );
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).expect("write head");
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).expect("write body");
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    split_response(&raw)
}

fn split_response(raw: &[u8]) -> (u16, String, Vec<u8>) {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response has a head/body split");
    let head = String::from_utf8(raw[..split].to_vec()).expect("utf-8 head");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut body = raw[split + 4..].to_vec();
    if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        body = dechunk(&body);
    }
    (status, head, body)
}

/// Reassemble a chunked body (sizes are hex, no extensions used here).
fn dechunk(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let eol = b
            .windows(2)
            .position(|w| w == b"\r\n")
            .expect("chunk size line");
        let size_str =
            std::str::from_utf8(&b[..eol]).expect("utf-8 chunk size");
        let size =
            usize::from_str_radix(size_str.trim(), 16).expect("hex size");
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&b[eol + 2..eol + 2 + size]);
        b = &b[eol + 2 + size + 2..]; // skip the chunk's trailing CRLF
    }
}

fn json_body(body: &[u8]) -> JsonValue {
    parse(body).unwrap_or_else(|e| {
        panic!(
            "response body is not valid JSON: {e}\n{}",
            String::from_utf8_lossy(body)
        )
    })
}

fn error_kind(body: &[u8]) -> String {
    json_body(body)
        .get("error")
        .and_then(|e| e.get("kind"))
        .and_then(JsonValue::as_str)
        .expect("structured error body")
        .to_string()
}

/// Poll `/v1/stats` until `pred` holds or the deadline passes; returns
/// the last snapshot either way.
fn poll_stats(
    addr: SocketAddr,
    deadline: Duration,
    pred: impl Fn(&JsonValue) -> bool,
) -> JsonValue {
    let start = Instant::now();
    loop {
        let (status, _, body) = request(addr, "GET", "/v1/stats", None);
        assert_eq!(status, 200, "stats must stay readable while serving");
        let v = json_body(&body);
        if pred(&v) || start.elapsed() > deadline {
            return v;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn counter(v: &JsonValue, field: &str) -> f64 {
    v.get(field).and_then(JsonValue::as_num).unwrap_or(-1.0)
}

// ------------------------------------------------------------- tests

#[test]
fn http_generate_matches_serve_and_streaming_concatenates() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let prompts = ["copy ab", "rev abcd", "up hi"];

    // ground truth straight through the engine, same settings
    let mut reference = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .build()
        .unwrap();
    let expected: Vec<String> = reference
        .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
        .unwrap()
        .outputs
        .into_iter()
        .map(|o| o.text)
        .collect();
    drop(reference);

    let mut session = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            let (status, _, body) = request(addr, "GET", "/healthz", None);
            assert_eq!(status, 200);
            assert_eq!(json_body(&body).to_string(), r#"{"status":"ok"}"#);

            for (prompt, expect) in prompts.iter().zip(&expected) {
                // non-streamed: one JSON body, bit-identical text
                let body = format!(r#"{{"prompt":{}}}"#, JsonValue::s(*prompt));
                let (status, _, resp) =
                    request(addr, "POST", "/v1/generate", Some(&body));
                assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
                let v = json_body(&resp);
                assert_eq!(v.get("outcome").and_then(JsonValue::as_str),
                           Some("done"));
                assert_eq!(
                    v.get("text").and_then(JsonValue::as_str),
                    Some(expect.as_str()),
                    "HTTP generate diverged from Session::serve for {prompt:?}"
                );

                // streamed: chunked JSON lines; the token fields
                // concatenate to the done line's text, which matches too
                let body = format!(
                    r#"{{"prompt":{},"stream":true}}"#,
                    JsonValue::s(*prompt)
                );
                let (status, head, resp) =
                    request(addr, "POST", "/v1/generate", Some(&body));
                assert_eq!(status, 200);
                assert!(
                    head.to_ascii_lowercase()
                        .contains("transfer-encoding: chunked"),
                    "streamed responses use chunked transfer"
                );
                let text = String::from_utf8(resp).unwrap();
                let lines: Vec<JsonValue> = text
                    .lines()
                    .map(|l| json_body(l.as_bytes()))
                    .collect();
                let (done, tokens) = lines.split_last().expect("a done line");
                assert_eq!(done.get("done"), Some(&JsonValue::Bool(true)));
                assert_eq!(done.get("outcome").and_then(JsonValue::as_str),
                           Some("done"));
                let concat: String = tokens
                    .iter()
                    .map(|l| {
                        l.get("token")
                            .and_then(JsonValue::as_str)
                            .expect("token line")
                    })
                    .collect();
                assert_eq!(
                    done.get("text").and_then(JsonValue::as_str),
                    Some(concat.as_str()),
                    "streamed tokens must concatenate to the final text"
                );
                assert_eq!(&concat, expect, "streamed != serve for {prompt:?}");
            }

            // the error contract, all on live connections:
            // malformed JSON → 400 with a structured parse_error body
            let (status, _, resp) =
                request(addr, "POST", "/v1/generate", Some("{"));
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "parse_error");
            // missing prompt
            let (status, _, resp) =
                request(addr, "POST", "/v1/generate", Some("{}"));
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "missing_field");
            // adapter this session does not serve
            let (status, _, resp) = request(
                addr,
                "POST",
                "/v1/generate",
                Some(r#"{"prompt":"p","adapter":"no-such-adapter"}"#),
            );
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "unknown_adapter");
            // wrong method / unknown route
            let (status, _, resp) =
                request(addr, "GET", "/v1/generate", None);
            assert_eq!(status, 405);
            assert_eq!(error_kind(&resp), "method_not_allowed");
            let (status, _, resp) = request(addr, "GET", "/nope", None);
            assert_eq!(status, 404);
            assert_eq!(error_kind(&resp), "not_found");

            // stats catch up to all six completed generations
            let want = (2 * prompts.len()) as f64;
            let st = poll_stats(addr, Duration::from_secs(10), |v| {
                counter(v, "completed") == want
            });
            assert_eq!(counter(&st, "submitted"), want);
            assert_eq!(counter(&st, "completed"), want);

            let (status, _, body) =
                request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
            assert_eq!(
                json_body(&body).to_string(),
                r#"{"shutting_down":true}"#
            );
        });
        server.run(&mut session).unwrap()
    });

    assert_eq!(report.outputs.len(), 2 * prompts.len());
    for out in &report.outputs {
        assert_eq!(out.outcome, JobOutcome::Done);
    }
    assert_eq!(report.stats.completed, 2 * prompts.len() as u64);
}

#[test]
fn mid_stream_disconnect_cancels_the_job() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    // plenty of decode steps so the disconnect lands well before the
    // generation could finish on its own
    let sampler = Sampler { max_new_tokens: 64, ..Sampler::default() };
    let mut session = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // open a streamed generation and hang up immediately: the
            // worker's next chunk write fails, which must flip the
            // job's cancel handle
            {
                let mut stream = TcpStream::connect(addr).expect("connect");
                let body = r#"{"prompt":"copy abcdefgh","stream":true}"#;
                let head = format!(
                    "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: {}\r\n\r\n",
                    body.len()
                );
                stream.write_all(head.as_bytes()).expect("write");
                stream.write_all(body.as_bytes()).expect("write");
                // dropped here: FIN now, RST on the server's next write
            }
            // stats stay readable throughout, and the cancellation
            // shows up in them — the row was freed, not leaked
            let st = poll_stats(addr, Duration::from_secs(30), |v| {
                counter(v, "cancelled") >= 1.0
            });
            assert!(
                counter(&st, "cancelled") >= 1.0,
                "disconnect never cancelled the job: {st}"
            );
            assert_eq!(
                counter(&st, "active_rows"),
                0.0,
                "cancelled row must be freed"
            );

            let (status, _, _) = request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
        });
        server.run(&mut session).unwrap()
    });

    assert!(
        report
            .outputs
            .iter()
            .any(|o| o.outcome == JobOutcome::Cancelled),
        "the disconnected job must end Cancelled in the report"
    );
    assert_eq!(report.stats.cancelled, 1);
}
