//! End-to-end HTTP serving over real artifacts and a real loopback
//! TCP socket: bit-identity between `POST /v1/generate`, its streamed
//! variant, and `Session::serve`; the structured-JSON error contract;
//! live `/v1/stats` polling; and the disconnect→cancel path. Each test
//! skips with a message when artifacts are not built (the wire-format
//! functions themselves are covered without artifacts by the
//! `serve::server` unit tests and `python/tests/test_serve_mirror.py`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::rc::Rc;
use std::time::{Duration, Instant};

use qlora::engine::{Engine, GenRequest, JobOutcome, Sampler};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::serve::json::{parse, JsonValue};
use qlora::serve::{HttpServer, ServerConfig};
use qlora::util::faults::Faults;

// PjRtClient is single-threaded (Rc internally), so each test builds
// its own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the HTTP serving tests"
        );
        return None;
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipped: PJRT CPU runtime unavailable: {e:#}");
            return None;
        }
    };
    Some((Rc::new(rt), manifest))
}

fn engine(rt: &Rc<Runtime>, manifest: &Manifest) -> Option<Engine> {
    match Engine::new(rt.clone(), manifest, "e2e") {
        Ok(eng) => Some(eng),
        Err(e) => {
            eprintln!("skipped: artifact \"e2e\" unavailable: {e:#}");
            None
        }
    }
}

// ------------------------------------------------------- tiny client

/// One `Connection: close` request; returns (status, headers, body).
/// The server closes after every such exchange, so reading to EOF is
/// the framing.
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("read timeout");
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n"
    );
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).expect("write head");
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).expect("write body");
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    split_response(&raw)
}

fn split_response(raw: &[u8]) -> (u16, String, Vec<u8>) {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response has a head/body split");
    let head = String::from_utf8(raw[..split].to_vec()).expect("utf-8 head");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut body = raw[split + 4..].to_vec();
    if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        body = dechunk(&body);
    }
    (status, head, body)
}

/// Reassemble a chunked body (sizes are hex, no extensions used here).
fn dechunk(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let eol = b
            .windows(2)
            .position(|w| w == b"\r\n")
            .expect("chunk size line");
        let size_str =
            std::str::from_utf8(&b[..eol]).expect("utf-8 chunk size");
        let size =
            usize::from_str_radix(size_str.trim(), 16).expect("hex size");
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&b[eol + 2..eol + 2 + size]);
        b = &b[eol + 2 + size + 2..]; // skip the chunk's trailing CRLF
    }
}

fn json_body(body: &[u8]) -> JsonValue {
    parse(body).unwrap_or_else(|e| {
        panic!(
            "response body is not valid JSON: {e}\n{}",
            String::from_utf8_lossy(body)
        )
    })
}

fn error_kind(body: &[u8]) -> String {
    json_body(body)
        .get("error")
        .and_then(|e| e.get("kind"))
        .and_then(JsonValue::as_str)
        .expect("structured error body")
        .to_string()
}

/// Poll `/v1/stats` until `pred` holds or the deadline passes; returns
/// the last snapshot either way.
fn poll_stats(
    addr: SocketAddr,
    deadline: Duration,
    pred: impl Fn(&JsonValue) -> bool,
) -> JsonValue {
    let start = Instant::now();
    loop {
        let (status, _, body) = request(addr, "GET", "/v1/stats", None);
        assert_eq!(status, 200, "stats must stay readable while serving");
        let v = json_body(&body);
        if pred(&v) || start.elapsed() > deadline {
            return v;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn counter(v: &JsonValue, field: &str) -> f64 {
    v.get(field).and_then(JsonValue::as_num).unwrap_or(-1.0)
}

// ------------------------------------------------------------- tests

#[test]
fn http_generate_matches_serve_and_streaming_concatenates() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let prompts = ["copy ab", "rev abcd", "up hi"];

    // ground truth straight through the engine, same settings
    let mut reference = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .build()
        .unwrap();
    let expected: Vec<String> = reference
        .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
        .unwrap()
        .outputs
        .into_iter()
        .map(|o| o.text)
        .collect();
    drop(reference);

    let mut session = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            let (status, _, body) = request(addr, "GET", "/healthz", None);
            assert_eq!(status, 200);
            assert_eq!(json_body(&body).to_string(), r#"{"status":"ok"}"#);

            for (prompt, expect) in prompts.iter().zip(&expected) {
                // non-streamed: one JSON body, bit-identical text
                let body = format!(r#"{{"prompt":{}}}"#, JsonValue::s(*prompt));
                let (status, _, resp) =
                    request(addr, "POST", "/v1/generate", Some(&body));
                assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
                let v = json_body(&resp);
                assert_eq!(v.get("outcome").and_then(JsonValue::as_str),
                           Some("done"));
                assert_eq!(
                    v.get("text").and_then(JsonValue::as_str),
                    Some(expect.as_str()),
                    "HTTP generate diverged from Session::serve for {prompt:?}"
                );

                // streamed: chunked JSON lines; the token fields
                // concatenate to the done line's text, which matches too
                let body = format!(
                    r#"{{"prompt":{},"stream":true}}"#,
                    JsonValue::s(*prompt)
                );
                let (status, head, resp) =
                    request(addr, "POST", "/v1/generate", Some(&body));
                assert_eq!(status, 200);
                assert!(
                    head.to_ascii_lowercase()
                        .contains("transfer-encoding: chunked"),
                    "streamed responses use chunked transfer"
                );
                let text = String::from_utf8(resp).unwrap();
                let lines: Vec<JsonValue> = text
                    .lines()
                    .map(|l| json_body(l.as_bytes()))
                    .collect();
                let (done, tokens) = lines.split_last().expect("a done line");
                assert_eq!(done.get("done"), Some(&JsonValue::Bool(true)));
                assert_eq!(done.get("outcome").and_then(JsonValue::as_str),
                           Some("done"));
                let concat: String = tokens
                    .iter()
                    .map(|l| {
                        l.get("token")
                            .and_then(JsonValue::as_str)
                            .expect("token line")
                    })
                    .collect();
                assert_eq!(
                    done.get("text").and_then(JsonValue::as_str),
                    Some(concat.as_str()),
                    "streamed tokens must concatenate to the final text"
                );
                assert_eq!(&concat, expect, "streamed != serve for {prompt:?}");
            }

            // the error contract, all on live connections:
            // malformed JSON → 400 with a structured parse_error body
            let (status, _, resp) =
                request(addr, "POST", "/v1/generate", Some("{"));
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "parse_error");
            // missing prompt
            let (status, _, resp) =
                request(addr, "POST", "/v1/generate", Some("{}"));
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "missing_field");
            // adapter this session does not serve
            let (status, _, resp) = request(
                addr,
                "POST",
                "/v1/generate",
                Some(r#"{"prompt":"p","adapter":"no-such-adapter"}"#),
            );
            assert_eq!(status, 400);
            assert_eq!(error_kind(&resp), "unknown_adapter");
            // wrong method / unknown route
            let (status, _, resp) =
                request(addr, "GET", "/v1/generate", None);
            assert_eq!(status, 405);
            assert_eq!(error_kind(&resp), "method_not_allowed");
            let (status, _, resp) = request(addr, "GET", "/nope", None);
            assert_eq!(status, 404);
            assert_eq!(error_kind(&resp), "not_found");

            // stats catch up to all six completed generations
            let want = (2 * prompts.len()) as f64;
            let st = poll_stats(addr, Duration::from_secs(10), |v| {
                counter(v, "completed") == want
            });
            assert_eq!(counter(&st, "submitted"), want);
            assert_eq!(counter(&st, "completed"), want);

            let (status, _, body) =
                request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
            assert_eq!(
                json_body(&body).to_string(),
                r#"{"shutting_down":true}"#
            );
        });
        server.run(&mut session).unwrap()
    });

    assert_eq!(report.outputs.len(), 2 * prompts.len());
    for out in &report.outputs {
        assert_eq!(out.outcome, JobOutcome::Done);
    }
    assert_eq!(report.stats.completed, 2 * prompts.len() as u64);
}

#[test]
fn mid_stream_disconnect_cancels_the_job() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    // plenty of decode steps so the disconnect lands well before the
    // generation could finish on its own
    let sampler = Sampler { max_new_tokens: 64, ..Sampler::default() };
    let mut session = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // open a streamed generation and hang up immediately: the
            // worker's next chunk write fails, which must flip the
            // job's cancel handle
            {
                let mut stream = TcpStream::connect(addr).expect("connect");
                let body = r#"{"prompt":"copy abcdefgh","stream":true}"#;
                let head = format!(
                    "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: {}\r\n\r\n",
                    body.len()
                );
                stream.write_all(head.as_bytes()).expect("write");
                stream.write_all(body.as_bytes()).expect("write");
                // dropped here: FIN now, RST on the server's next write
            }
            // stats stay readable throughout, and the cancellation
            // shows up in them — the row was freed, not leaked
            let st = poll_stats(addr, Duration::from_secs(30), |v| {
                counter(v, "cancelled") >= 1.0
            });
            assert!(
                counter(&st, "cancelled") >= 1.0,
                "disconnect never cancelled the job: {st}"
            );
            assert_eq!(
                counter(&st, "active_rows"),
                0.0,
                "cancelled row must be freed"
            );

            let (status, _, _) = request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
        });
        server.run(&mut session).unwrap()
    });

    assert!(
        report
            .outputs
            .iter()
            .any(|o| o.outcome == JobOutcome::Cancelled),
        "the disconnected job must end Cancelled in the report"
    );
    assert_eq!(report.stats.cancelled, 1);
}

#[test]
fn worker_panic_is_contained_and_server_stays_healthy() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let mut session =
        eng.session().greedy(true).build().unwrap();
    // the first accepted connection hits an injected panic inside its
    // handler (worker-panic, p=1, capped at one firing); containment
    // means the worker catches it, counts a restart, and keeps serving
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        faults: Faults::from_spec("seed=1,worker-panic=1x1").unwrap(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // the doomed connection: the handler panics before reading,
            // so the client just sees the connection drop — no response
            {
                let mut stream = TcpStream::connect(addr).expect("connect");
                let _ = stream.write_all(
                    b"GET /healthz HTTP/1.1\r\nHost: t\r\n\
                      Connection: close\r\n\r\n",
                );
                let mut sink = Vec::new();
                let _ = stream.read_to_end(&mut sink);
                assert!(
                    sink.is_empty(),
                    "the panicked handler must not have answered"
                );
            }
            // the server survives: liveness, stats, and the restart
            // counter all answer on fresh connections
            let (status, _, body) = request(addr, "GET", "/healthz", None);
            assert_eq!(status, 200, "server died with the worker panic");
            assert_eq!(json_body(&body).to_string(), r#"{"status":"ok"}"#);
            let st = poll_stats(addr, Duration::from_secs(10), |v| {
                counter(v, "worker_restarts") >= 1.0
            });
            assert_eq!(
                counter(&st, "worker_restarts"),
                1.0,
                "the caught panic must be counted: {st}"
            );
            let (status, _, _) = request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
        });
        server.run(&mut session).unwrap()
    });
    assert_eq!(report.stats.worker_restarts, 1);
}

#[test]
fn connection_cap_sheds_with_503_and_retry_after() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let mut session =
        eng.session().greedy(true).build().unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        max_connections: 1,
        retry_after_secs: 3,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // hold one keep-alive connection so the cap (1) is full
            let mut held = TcpStream::connect(addr).expect("connect");
            held.write_all(
                b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n",
            )
            .expect("write");
            let mut buf = [0u8; 1024];
            let n = held.read(&mut buf).expect("healthz response");
            assert!(n > 0);
            // the next connection is over the cap: turned away with a
            // structured 503 and the configured Retry-After
            let (status, head, body) =
                request(addr, "GET", "/healthz", None);
            assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
            assert_eq!(error_kind(&body), "overloaded");
            assert!(
                head.to_ascii_lowercase().contains("retry-after: 3"),
                "Retry-After must be advertised:\n{head}"
            );
            drop(held); // release the cap, then stop the server
            // the worker needs a moment to notice the FIN and release
            // its connection slot — retry until under the cap again
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                let (status, _, _) =
                    request(addr, "POST", "/v1/shutdown", None);
                if status == 200 {
                    break;
                }
                assert!(
                    Instant::now() < deadline,
                    "shutdown kept bouncing off the connection cap"
                );
                std::thread::sleep(Duration::from_millis(25));
            }
        });
        server.run(&mut session).unwrap()
    });
    assert!(
        report.stats.shed_requests >= 1,
        "the refused connection must be counted as shed"
    );
}

#[test]
fn queue_watermark_sheds_with_429_and_retry_after() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    // slow every decode step down (decode-delay, p=1) so a burst of
    // requests piles up behind the watermark instead of completing
    // before the shed check can ever observe a backlog
    let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
    let mut session = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .faults(Faults::from_spec("seed=2,delay-ms=150,decode-delay=1").unwrap())
        .build()
        .unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        max_queue: 2,
        retry_after_secs: 1,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // 8 concurrent generations against a watermark of 2: the
            // overflow must come back as 429 + Retry-After, the rest
            // complete normally
            let outcomes: Vec<(u16, String, Vec<u8>)> =
                std::thread::scope(|burst| {
                    let handles: Vec<_> = (0..8)
                        .map(|i| {
                            burst.spawn(move || {
                                let body = format!(
                                    r#"{{"prompt":"copy ab{i}"}}"#
                                );
                                request(
                                    addr,
                                    "POST",
                                    "/v1/generate",
                                    Some(&body),
                                )
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
            let shed: Vec<_> =
                outcomes.iter().filter(|(s, ..)| *s == 429).collect();
            let served =
                outcomes.iter().filter(|(s, ..)| *s == 200).count();
            assert!(
                !shed.is_empty(),
                "a burst of 8 against watermark 2 must shed something: \
                 statuses {:?}",
                outcomes.iter().map(|(s, ..)| *s).collect::<Vec<_>>()
            );
            assert!(served >= 1, "the watermark must not shed everything");
            for (_, head, body) in &shed {
                assert_eq!(error_kind(body), "overloaded");
                assert!(
                    head.to_ascii_lowercase().contains("retry-after: 1"),
                    "shed responses must carry Retry-After:\n{head}"
                );
            }
            let st = poll_stats(addr, Duration::from_secs(10), |v| {
                counter(v, "shed_requests") >= 1.0
            });
            assert!(counter(&st, "shed_requests") >= 1.0, "{st}");
            let (status, _, _) = request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
        });
        server.run(&mut session).unwrap()
    });
    assert!(report.stats.shed_requests >= 1);
}

#[test]
fn requests_during_drain_get_structured_503() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let mut session =
        eng.session().greedy(true).build().unwrap();
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        retry_after_secs: 2,
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();

    let report = std::thread::scope(|scope| {
        scope.spawn(|| {
            // park several connections with the request head written but
            // the body incomplete: their workers sit in the body read
            let body = r#"{"prompt":"copy abcd"}"#;
            let mut parked: Vec<TcpStream> = (0..5)
                .map(|_| {
                    let mut s =
                        TcpStream::connect(addr).expect("connect");
                    s.set_read_timeout(Some(Duration::from_secs(10)))
                        .unwrap();
                    let head = format!(
                        "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                         Connection: close\r\nContent-Length: {}\r\n\r\n",
                        body.len()
                    );
                    s.write_all(head.as_bytes()).expect("write head");
                    // half the body only — the request is not complete
                    s.write_all(&body.as_bytes()[..4]).expect("write");
                    s
                })
                .collect();
            // begin the drain, then complete the parked bodies: each
            // request now *arrives* during shutdown and must get the
            // structured draining 503, not a reset
            let (status, _, _) = request(addr, "POST", "/v1/shutdown", None);
            assert_eq!(status, 200);
            let mut drained = 0;
            for s in parked.iter_mut() {
                let _ = s.write_all(&body.as_bytes()[4..]);
            }
            for mut s in parked {
                let mut raw = Vec::new();
                if s.read_to_end(&mut raw).is_err() || raw.is_empty() {
                    // lost the 100 ms idle-poll race on this connection
                    // (the worker saw shutdown before our bytes): a
                    // dropped connection, tolerated for a minority
                    continue;
                }
                let (status, head, resp) = split_response(&raw);
                assert_eq!(
                    status,
                    503,
                    "{}",
                    String::from_utf8_lossy(&resp)
                );
                assert_eq!(error_kind(&resp), "draining");
                assert!(
                    head.to_ascii_lowercase().contains("retry-after: 2"),
                    "draining 503 must carry Retry-After:\n{head}"
                );
                drained += 1;
            }
            assert!(
                drained >= 1,
                "no parked request observed the draining 503"
            );
        });
        server.run(&mut session).unwrap()
    });
    assert!(report.stats.shed_requests >= 1);
}
