//! Smoke tests over the experiment harness: every analytic/simulated
//! experiment runs in fast mode and emits its headline shape-check lines;
//! one real-training experiment runs when artifacts are present.

use std::rc::Rc;

use qlora::experiments::{runner, Ctx};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;

fn results_dir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join("qlora_results_test");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn analytic_experiments_run_fast() {
    let ctx = Ctx { rt: None, manifest: None, seed: 42, fast: true };
    let dir = results_dir();
    for (id, needs, _, _) in runner::registry() {
        if needs {
            continue;
        }
        let out = runner::run_one(id, &ctx, &dir)
            .unwrap_or_else(|e| panic!("{id} failed: {e:#}"));
        assert!(out.contains("=="), "{id} produced no table");
        assert!(dir.join(format!("{id}.txt")).exists());
    }
}

#[test]
fn table2_shape_lines() {
    let ctx = Ctx { rt: None, manifest: None, seed: 7, fast: true };
    let out = runner::run_one("table2", &ctx, &results_dir()).unwrap();
    assert!(out.contains("NFloat4 + DQ"));
    assert!(out.contains("Int4"));
}

#[test]
fn unknown_experiment_is_helpful() {
    let ctx = Ctx { rt: None, manifest: None, seed: 7, fast: true };
    let err = runner::run_one("table99", &ctx, &results_dir()).unwrap_err();
    assert!(format!("{err}").contains("available"));
}

#[test]
fn training_experiment_needs_runtime_error() {
    let ctx = Ctx { rt: None, manifest: None, seed: 7, fast: true };
    let err = runner::run_one("fig4", &ctx, &results_dir()).unwrap_err();
    assert!(format!("{err:#}").contains("artifacts"));
}

#[test]
fn one_training_experiment_end_to_end() {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the training experiment"
        );
        return;
    };
    let rt = Rc::new(Runtime::cpu().unwrap());
    let ctx = Ctx { rt: Some(rt), manifest: Some(manifest), seed: 1,
                    fast: true };
    // table10 is the cheapest real-training experiment (one artifact)
    let out = runner::run_one("table10", &ctx, &results_dir()).unwrap();
    assert!(out.contains("claim check"), "{out}");
}
