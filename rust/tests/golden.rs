//! Cross-boundary golden tests: the Rust `quant` crate vs the Python
//! reference (`ref.py`), over the vectors `aot.py` emits. Codes must match
//! bit-for-bit; floats to f32 round-off. Skips (with a message) when
//! artifacts are not built.

use std::path::PathBuf;

use qlora::quant::codebook::{Codebook, DType};
use qlora::quant::double::{double_dequantize, double_quantize};
use qlora::quant::{dequantize_blockwise, quantize_blockwise};
use qlora::runtime::artifact::Manifest;
use qlora::tensorio::{find, read_tensors, Tensor};
use qlora::util::json::Value;

fn load_golden() -> Option<(Vec<Tensor>, Value)> {
    let dir = Manifest::default_dir();
    let manifest = dir.join("manifest.json");
    if !manifest.exists() {
        eprintln!("golden tests skipped: run `make artifacts` first");
        return None;
    }
    let raw = Value::parse(&std::fs::read_to_string(manifest).unwrap())
        .unwrap();
    let tensors =
        read_tensors(&dir.join("golden.tensors")).expect("golden tensors");
    Some((tensors, raw))
}

#[test]
fn codebooks_bit_identical() {
    let Some((tensors, _)) = load_golden() else { return };
    for dt in [DType::NF4, DType::FP4E2M1, DType::FP4E3M0, DType::Int4,
               DType::Int8, DType::FP8E4M3] {
        let py = find(&tensors, &format!("codebook/{}", dt.name()))
            .unwrap()
            .to_f32()
            .unwrap();
        let rs = Codebook::new(dt).values;
        assert_eq!(py.len(), rs.len(), "{dt:?} size");
        for (i, (a, b)) in py.iter().zip(rs.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{dt:?}[{i}]: python {a} vs rust {b}"
            );
        }
    }
}

#[test]
fn quantize_cases_bit_exact() {
    let Some((tensors, raw)) = load_golden() else { return };
    let cases = raw.get("golden").unwrap().get("cases").unwrap();
    for case in cases.arr().unwrap() {
        let name = case.get("name").unwrap().str().unwrap();
        if name == "dq" {
            continue; // separate test below
        }
        let dtype =
            DType::from_name(case.get("dtype").unwrap().str().unwrap())
                .unwrap();
        let block = case.get("block").unwrap().usize().unwrap();
        let input =
            find(&tensors, &format!("{name}/input")).unwrap().to_f32()
                .unwrap();
        let py_codes = &find(&tensors, &format!("{name}/codes")).unwrap().data;
        let py_absmax = find(&tensors, &format!("{name}/absmax"))
            .unwrap()
            .to_f32()
            .unwrap();
        let py_deq = find(&tensors, &format!("{name}/dequant"))
            .unwrap()
            .to_f32()
            .unwrap();
        let cb = Codebook::new(dtype);
        let (codes, absmax) = quantize_blockwise(&input, &cb, block).unwrap();
        // codes bit-for-bit
        let mismatches =
            codes.iter().zip(py_codes.iter()).filter(|(a, b)| a != b).count();
        assert_eq!(mismatches, 0,
                   "{name} ({dtype:?}): {mismatches}/{} code mismatches",
                   codes.len());
        for (a, b) in absmax.iter().zip(py_absmax.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{name} absmax");
        }
        let deq = dequantize_blockwise(&codes, &absmax, &cb, block).unwrap();
        for (a, b) in deq.iter().zip(py_deq.iter()) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0),
                    "{name} dequant {a} vs {b}");
        }
    }
}

#[test]
fn double_quant_cross_check() {
    let Some((tensors, _)) = load_golden() else { return };
    let input = find(&tensors, "dq/input").unwrap().to_f32().unwrap();
    let py_deq = find(&tensors, "dq/dequant").unwrap().to_f32().unwrap();
    let py_mean = find(&tensors, "dq/mean").unwrap().to_f32().unwrap()[0];
    let cb = Codebook::new(DType::NF4);
    let (codes, absmax) = quantize_blockwise(&input, &cb, 64).unwrap();
    let dq = double_quantize(&absmax, 256).unwrap();
    // mean: XLA tree-reduce vs our f64 accumulate — equal to f32 eps
    assert!((dq.mean - py_mean).abs() <= 1e-5 * py_mean.abs().max(1.0),
            "mean {} vs {}", dq.mean, py_mean);
    let am = double_dequantize(&dq).unwrap();
    let deq = dequantize_blockwise(&codes, &am, &cb, 64).unwrap();
    let mut worst = 0f32;
    for (a, b) in deq.iter().zip(py_deq.iter()) {
        worst = worst.max((a - b).abs());
    }
    // FP8 codes of near-boundary constants may differ by the mean's last
    // ulp; the dequantized weights must still agree to one FP8 step
    assert!(worst < 2e-3, "worst dequant deviation {worst}");
}

#[test]
fn kernel_vectors_match_native_quant() {
    // the quickstart's pallas test vectors must agree with native Rust
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let vectors = read_tensors(&dir.join("kernel_vectors.tensors")).unwrap();
    let codes_t = find(&vectors, "dequant/codes").unwrap();
    let absmax = find(&vectors, "dequant/absmax").unwrap().to_f32().unwrap();
    let expected =
        find(&vectors, "dequant/expected").unwrap().to_f32().unwrap();
    let cb = Codebook::new(DType::NF4);
    let deq =
        dequantize_blockwise(&codes_t.data, &absmax, &cb, 64).unwrap();
    for (a, b) in deq.iter().zip(expected.iter()) {
        assert!((a - b).abs() < 1e-6);
    }
    let _ = PathBuf::new();
}
