//! Request-lifecycle serving over real artifacts: typed outcomes,
//! cancellation, deadlines, token-budget admission and server stats
//! through `Session::serve`, end to end. Each test skips with a message
//! when artifacts are not built, so `cargo test -q` is green from a
//! fresh clone; the pure scheduling policy itself is covered without
//! artifacts by the `engine::scheduler` unit tests and
//! `tests/prop_scheduler.rs`.

use std::rc::Rc;
use std::time::Duration;

use qlora::engine::{
    DecodeMode, Engine, GenRequest, JobOutcome, Priority, Sampler,
};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;

// PjRtClient is single-threaded (Rc internally), so each test builds its
// own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the serve tests"
        );
        return None;
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipped: PJRT CPU runtime unavailable: {e:#}");
            return None;
        }
    };
    Some((Rc::new(rt), manifest))
}

fn engine(rt: &Rc<Runtime>, manifest: &Manifest) -> Option<Engine> {
    match Engine::new(rt.clone(), manifest, "e2e") {
        Ok(eng) => Some(eng),
        Err(e) => {
            eprintln!("skipped: artifact \"e2e\" unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn serve_matches_generate_batch_and_reports_done_outcomes() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let prompts = ["copy ab", "rev abcd", "up hi"];
    let mut s = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let batch = s.generate_batch(&prompts).unwrap();
    let report = s
        .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
        .unwrap();
    assert_eq!(report.outputs.len(), prompts.len());
    for (out, expect) in report.outputs.iter().zip(batch.iter()) {
        assert_eq!(out.outcome, JobOutcome::Done, "plain prompts end Done");
        assert_eq!(&out.text, expect, "serve == generate_batch (greedy)");
    }
    let st = &report.stats;
    assert_eq!(st.submitted, prompts.len() as u64);
    assert_eq!(st.completed, prompts.len() as u64);
    assert_eq!(st.cancelled + st.deadline_exceeded + st.preemptions, 0);
    assert!(st.elapsed > Duration::from_secs(0), "elapsed was filled in");
    if st.tokens_generated > 0 {
        assert!(st.tokens_per_sec() > 0.0);
    }
}

#[test]
fn mixed_priority_workload_with_cancellation_and_deadline() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let batch = eng.spec.cfg.batch;
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    let mut s = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    // more requests than rows, mixed priorities, one cancellable, one
    // with an already-expired deadline (it must never run)
    let mut requests: Vec<GenRequest> = (0..batch + 2)
        .map(|i| {
            GenRequest::new(format!("rev p{i}")).priority(match i % 3 {
                0 => Priority::High,
                1 => Priority::Normal,
                _ => Priority::Low,
            })
        })
        .collect();
    let (cancellable, handle) =
        GenRequest::new("copy cancel me").cancellable();
    requests.push(cancellable);
    let n_cancel = requests.len() - 1;
    requests.push(
        GenRequest::new("copy too late")
            .deadline(Duration::from_millis(0)),
    );
    let n_deadline = requests.len() - 1;
    let n = requests.len();

    // cancel mid-flight from the step callback; record how quickly the
    // preemption lands
    let mut cancel_step = None;
    let mut preempted_step = None;
    let report = s
        .serve_with(requests, |p| {
            if p.step == 1 {
                cancel_step = Some(p.step);
                handle.cancel();
            }
            if p.stats.preemptions > 0 && preempted_step.is_none() {
                preempted_step = Some(p.step);
            }
        })
        .unwrap();

    assert_eq!(report.outputs.len(), n);
    assert_eq!(
        report.outputs[n_deadline].outcome,
        JobOutcome::DeadlineExceeded,
        "expired deadline must never run"
    );
    assert_eq!(report.outputs[n_deadline].text, "");
    assert_eq!(
        report.outputs[n_cancel].outcome,
        JobOutcome::Cancelled,
        "cancel handle must retire the request"
    );
    for (i, out) in report.outputs.iter().enumerate() {
        if i != n_cancel && i != n_deadline {
            assert_eq!(out.outcome, JobOutcome::Done, "request {i}");
        }
    }
    // the cancelled row was freed within one step of the cancel landing
    // (it may have been queued rather than in flight, in which case no
    // preemption is recorded at all — both are within-one-step retires)
    if let (Some(c), Some(p)) = (cancel_step, preempted_step) {
        assert!(
            p <= c + 1,
            "cancel at step {c} only freed the row at step {p}"
        );
    }
    let st = &report.stats;
    assert_eq!(st.submitted, n as u64);
    assert_eq!(st.completed, (n - 2) as u64);
    assert_eq!(st.cancelled, 1);
    assert_eq!(st.deadline_exceeded, 1);
    if st.tokens_generated > 0 {
        assert!(st.mean_ttft_us > 0.0, "ttft recorded with first tokens");
    }
}

#[test]
fn tight_token_budget_serializes_but_preserves_outputs() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    let prompts = ["copy ab", "rev cd", "up ef"];
    // a budget far below batch × seq_len: admission is gated by tokens,
    // not row count, so requests run (near-)serially — outputs must be
    // bit-identical to the roomy continuous batch all the same
    let mut tight = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .token_budget(16)
        .build()
        .unwrap();
    let report = tight
        .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
        .unwrap();
    let mut roomy = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .unwrap();
    let expect = roomy.generate_batch(&prompts).unwrap();
    for ((out, expect), p) in
        report.outputs.iter().zip(expect.iter()).zip(prompts.iter())
    {
        assert_eq!(out.outcome, JobOutcome::Done);
        assert_eq!(&out.text, expect, "budget changed the output for {p:?}");
    }
}

#[test]
fn prefix_sharing_on_and_off_produce_identical_greedy_outputs() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    // the shared-prefix workload: one "system prompt" repeated across
    // every request, distinct suffixes — sharing collapses the common
    // prefix blocks but must never change a single output token
    let prompts = ["rev shared a", "rev shared b", "rev shared c"];
    let mut texts = Vec::new();
    for sharing in [true, false] {
        let mut s = eng
            .session()
            .sampler(sampler.clone())
            .greedy(true)
            .kv_block_tokens(4)
            .prefix_sharing(sharing)
            .build()
            .unwrap();
        let report = s
            .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
            .unwrap();
        for out in &report.outputs {
            assert_eq!(out.outcome, JobOutcome::Done);
        }
        if sharing {
            assert!(
                report.stats.shared_block_hits > 0,
                "shared-prefix workload must actually share blocks"
            );
        } else {
            assert_eq!(report.stats.shared_block_hits, 0);
        }
        texts.push(
            report
                .outputs
                .into_iter()
                .map(|o| o.text)
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(
        texts[0], texts[1],
        "prefix sharing changed greedy outputs"
    );
}

#[test]
fn forcing_decode_modes_through_serve_agree() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = engine(&rt, &manifest) else { return };
    if !eng.has_cached_decode() {
        eprintln!("skipped: artifact \"e2e\" has no decode graphs");
        return;
    }
    let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
    let prompts = ["copy ab", "rev p0", "rev p1"];
    let mut texts = Vec::new();
    for mode in [DecodeMode::Cached, DecodeMode::Full] {
        let mut s = eng
            .session()
            .sampler(sampler.clone())
            .greedy(true)
            .decode(mode)
            .build()
            .unwrap();
        let report = s
            .serve(prompts.iter().map(|p| GenRequest::new(*p)).collect())
            .unwrap();
        texts.push(
            report
                .outputs
                .into_iter()
                .map(|o| o.text)
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(texts[0], texts[1], "cached serve diverged from full");
}
