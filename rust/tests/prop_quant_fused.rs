//! Fused-vs-scalar contract suite: the fused multicore kernels
//! (`quant::kernels`) must be **bit-identical** to the scalar reference
//! tier across all six dtypes × block sizes {32, 64, 256} × edge cases
//! (all-zero blocks, outlier blocks, ±absmax endpoints) × odd
//! thread-shard boundaries. This is the contract that lets every hot path
//! run fused while `rust/tests/golden.rs` keeps pinning the scalar tier
//! (and therefore both tiers) to the Python reference.

use qlora::quant::codebook::{nfk_codebook, Codebook, DType};
use qlora::quant::kernels::{
    dequantize_blockwise_fused, dequantize_fused_into, quantize_blockwise_fused,
    quantize_fused, Encoder,
};
use qlora::quant::tensor::{Constants, QuantizedTensor};
use qlora::quant::{
    dequantize_blockwise, pack_nibbles, quantize_blockwise, unpack_nibbles,
};
use qlora::util::prop::{self, gen};
use qlora::util::rng::Rng;

const DTYPES: [DType; 6] = [DType::NF4, DType::FP4E2M1, DType::FP4E3M0,
                            DType::Int4, DType::Int8, DType::FP8E4M3];
const BLOCKS: [usize; 3] = [32, 64, 256];
// deliberately awkward shard counts (incl. more shards than blocks)
const THREADS: [usize; 4] = [1, 3, 5, 7];

fn bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// Edge-case input families the suite sweeps in addition to random ones.
fn edge_inputs(rng: &mut Rng, n: usize, block: usize) -> Vec<Vec<f32>> {
    let mut cases = Vec::new();
    // all-zero tensor (absmax = 0 -> scale fallback path)
    cases.push(vec![0f32; n]);
    // normal with a zeroed-out block in the middle
    let mut z = rng.normal_vec_f32(n);
    let b = (n / block) / 2;
    for v in &mut z[b * block..(b + 1) * block] {
        *v = 0.0;
    }
    cases.push(z);
    // heavy outliers (LLM.int8 phenomenology)
    cases.push(gen::outlier_vec(rng, n, 0.05, 100.0));
    // exact ±absmax endpoints: every block contains +m and -m so the
    // normalized values hit exactly ±1.0 (the codebook endpoints)
    let mut e = rng.normal_vec_f32(n);
    for b in 0..n / block {
        e[b * block] = 3.5;
        e[b * block + block / 2] = -3.5;
    }
    cases.push(e);
    // tiny denormal-ish magnitudes (scale division stress)
    cases.push((0..n).map(|i| (i as f32 - n as f32 / 2.0) * 1e-30).collect());
    cases
}

#[test]
fn flat_fused_bit_identical_to_scalar() {
    for dt in DTYPES {
        let cb = Codebook::new(dt);
        for block in BLOCKS {
            prop::check(
                &format!("flat-fused-{}-b{block}", dt.name()),
                8,
                |rng| {
                    let nb = 1 + rng.below(9); // 1..9 blocks: odd shard splits
                    let n = nb * block;
                    let mut inputs = edge_inputs(rng, n, block);
                    inputs.push(gen::weight_vec(rng, n));
                    for x in inputs {
                        let (sc, sa) = quantize_blockwise(&x, &cb, block)
                            .unwrap();
                        let sd = dequantize_blockwise(&sc, &sa, &cb, block)
                            .unwrap();
                        for t in THREADS {
                            let (fc, fa) = quantize_blockwise_fused(
                                &x, &cb, block, Some(t),
                            )
                            .unwrap();
                            assert_eq!(fc, sc, "{dt:?} b{block} t{t} codes");
                            bits_eq(&fa, &sa, "absmax");
                            let fd = dequantize_blockwise_fused(
                                &fc, &fa, &cb, block, Some(t),
                            )
                            .unwrap();
                            bits_eq(&fd, &sd, "dequant");
                        }
                    }
                },
            );
        }
    }
}

#[test]
fn weight_container_fused_bit_identical_to_scalar() {
    // transpose + pack path: odd h (bytes straddle columns), odd shard
    // boundaries, DQ and raw constants
    for dt in [DType::NF4, DType::Int4, DType::Int8] {
        let cb = Codebook::new(dt);
        prop::check(&format!("container-fused-{}", dt.name()), 12, |rng| {
            let shapes = [(64, 2), (32, 6), (31, 64), (37, 32), (128, 16)];
            let (h, o) = shapes[rng.below(shapes.len())];
            let block = [32, 64][rng.below(2)];
            if (h * o) % block != 0 {
                return;
            }
            let w = gen::weight_vec(rng, h * o);
            // scalar reference via the materialized transpose
            let mut flat = vec![0f32; h * o];
            for i in 0..h {
                for j in 0..o {
                    flat[j * h + i] = w[i * o + j];
                }
            }
            let (sc, sa) = quantize_blockwise(&flat, &cb, block).unwrap();
            let sdata = if cb.len() <= 16 {
                pack_nibbles(&sc).unwrap()
            } else {
                sc.clone()
            };
            for t in THREADS {
                let (fdata, fa) =
                    quantize_fused(&w, (h, o), &cb, block, Some(t)).unwrap();
                assert_eq!(fdata, sdata, "{dt:?} {h}x{o} b{block} t{t} data");
                bits_eq(&fa, &sa, "absmax");
                // fused dequant == scalar unpack+dequant+untranspose
                let codes = if cb.len() <= 16 {
                    unpack_nibbles(&fdata)
                } else {
                    fdata.clone()
                };
                let sflat =
                    dequantize_blockwise(&codes, &fa, &cb, block).unwrap();
                let mut sw = vec![0f32; h * o];
                for j in 0..o {
                    for i in 0..h {
                        sw[i * o + j] = sflat[j * h + i];
                    }
                }
                let mut fw = vec![0f32; h * o];
                dequantize_fused_into(
                    &fdata, &fa, &cb, block, (h, o), &mut fw, Some(t),
                )
                .unwrap();
                bits_eq(&fw, &sw, "weight dequant");
            }
        });
    }
}

#[test]
fn tall_weights_cross_row_tile_boundaries() {
    // the fused dequantizer tiles output rows in chunks of 256; h > 256
    // (with shard bands both above and below one tile) must stay
    // bit-identical to the scalar pipeline — this is the branch every
    // production-sized weight (e.g. 4096x4096) takes
    let mut rng = Rng::new(77);
    let cb = Codebook::new(DType::NF4);
    for (h, o) in [(600, 2), (512, 3), (257, 8)] {
        let block = 8; // (h*o) % 8 == 0 for all three shapes
        let w = {
            let mut v = rng.normal_vec_f32(h * o);
            v[0] = 7.5; // endpoint in the first block
            v
        };
        let mut flat = vec![0f32; h * o];
        for i in 0..h {
            for j in 0..o {
                flat[j * h + i] = w[i * o + j];
            }
        }
        let (sc, sa) = quantize_blockwise(&flat, &cb, block).unwrap();
        let sdata = pack_nibbles(&sc).unwrap();
        let sflat = dequantize_blockwise(&sc, &sa, &cb, block).unwrap();
        let mut sw = vec![0f32; h * o];
        for j in 0..o {
            for i in 0..h {
                sw[i * o + j] = sflat[j * h + i];
            }
        }
        for t in [1, 2, 5] {
            let (fdata, fa) =
                quantize_fused(&w, (h, o), &cb, block, Some(t)).unwrap();
            assert_eq!(fdata, sdata, "h={h} o={o} t={t}");
            let mut fw = vec![0f32; h * o];
            dequantize_fused_into(
                &fdata, &fa, &cb, block, (h, o), &mut fw, Some(t),
            )
            .unwrap();
            bits_eq(&fw, &sw, "tall dequant");
        }
    }
}

#[test]
fn oversized_blocks_use_the_strided_fallback() {
    // block > 512 exceeds the gather scratch buffer: quantize_fused must
    // take the two-pass strided walk (packed and raw) bit-identically
    let mut rng = Rng::new(78);
    for (dt, block, h, o) in [(DType::NF4, 1024, 128, 16),
                              (DType::Int8, 600, 150, 12)] {
        let cb = Codebook::new(dt);
        assert_eq!((h * o) % block, 0);
        let w = rng.normal_vec_f32(h * o);
        let mut flat = vec![0f32; h * o];
        for i in 0..h {
            for j in 0..o {
                flat[j * h + i] = w[i * o + j];
            }
        }
        let (sc, sa) = quantize_blockwise(&flat, &cb, block).unwrap();
        let sdata = if cb.len() <= 16 {
            pack_nibbles(&sc).unwrap()
        } else {
            sc
        };
        for t in [1, 3] {
            let (fdata, fa) =
                quantize_fused(&w, (h, o), &cb, block, Some(t)).unwrap();
            assert_eq!(fdata, sdata, "{dt:?} block={block} t={t}");
            bits_eq(&fa, &sa, "oversized-block absmax");
        }
    }
}

#[test]
fn quantized_tensor_api_matches_scalar_oracle() {
    // the public container API (auto threads) across dtypes × DQ modes
    prop::check("qt-api-oracle", 24, |rng| {
        let dt = DTYPES[rng.below(DTYPES.len())];
        let dq = if rng.bool(0.5) { Some(256) } else { None };
        let (h, o) = (64, 1 + rng.below(8));
        let w = gen::weight_vec(rng, h * o);
        let f = QuantizedTensor::quantize(&w, (h, o), dt, 32, dq).unwrap();
        let s = QuantizedTensor::quantize_scalar(&w, (h, o), dt, 32, dq)
            .unwrap();
        assert_eq!(f.data, s.data, "{dt:?} dq={dq:?} data");
        match (&f.constants, &s.constants) {
            (Constants::Raw(a), Constants::Raw(b)) => bits_eq(a, b, "absmax"),
            (Constants::Double(a), Constants::Double(b)) => {
                assert_eq!(a.codes2, b.codes2, "codes2");
                bits_eq(&a.absmax2, &b.absmax2, "absmax2");
                assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "mean");
                assert_eq!((a.n, a.block2), (b.n, b.block2));
            }
            _ => panic!("constants kind diverged"),
        }
        bits_eq(
            &f.dequantize().unwrap(),
            &s.dequantize_scalar().unwrap(),
            "dequantize",
        );
    });
}

#[test]
fn double_quant_fused_bit_identical_to_scalar() {
    // the DQ leg runs fused on the hot path; its scalar twin is the
    // oracle — the two must agree bit-for-bit (including the padding
    // block and the mean)
    use qlora::quant::{
        double_dequantize, double_dequantize_scalar, double_quantize,
        double_quantize_scalar,
    };
    prop::check("dq-fused-oracle", 24, |rng| {
        let n = 1 + rng.below(1200); // exercises padding (n % 256 != 0)
        let am: Vec<f32> =
            (0..n).map(|_| (rng.normal().abs() * 0.3 + 2.0) as f32).collect();
        let f = double_quantize(&am, 256).unwrap();
        let s = double_quantize_scalar(&am, 256).unwrap();
        assert_eq!(f.codes2, s.codes2, "codes2");
        bits_eq(&f.absmax2, &s.absmax2, "absmax2");
        assert_eq!(f.mean.to_bits(), s.mean.to_bits(), "mean");
        assert_eq!((f.n, f.block2), (s.n, s.block2));
        bits_eq(
            &double_dequantize(&f).unwrap(),
            &double_dequantize_scalar(&s).unwrap(),
            "recovered constants",
        );
    });
}

#[test]
fn derived_nfk_codebooks_also_bit_identical() {
    // k<4 exercises the padded branchless encoder, k>4 the generic one
    for k in [2u32, 3, 5, 8] {
        let cb = nfk_codebook(k);
        prop::check(&format!("nfk-{k}-fused"), 8, |rng| {
            let n = 64 * (1 + rng.below(5));
            let x = gen::outlier_vec(rng, n, 0.02, 10.0);
            let (sc, sa) = quantize_blockwise(&x, &cb, 64).unwrap();
            let (fc, fa) = quantize_blockwise_fused(&x, &cb, 64, Some(3))
                .unwrap();
            assert_eq!(fc, sc);
            bits_eq(&fa, &sa, "absmax");
        });
    }
}

#[test]
fn encoder_specializations_agree_with_binary_search() {
    // direct Encoder contract over the normalized domain, all dtypes
    let mut rng = Rng::new(99);
    for dt in DTYPES {
        let cb = Codebook::new(dt);
        let enc = Encoder::new(&cb);
        for _ in 0..4000 {
            let x = rng.range_f64(-1.0, 1.0) as f32;
            assert_eq!(enc.encode(x), cb.encode(x), "{dt:?} x={x}");
        }
        for &v in &cb.values {
            assert_eq!(enc.encode(v), cb.encode(v), "{dt:?} value");
        }
        for &m in cb.midpoints() {
            assert_eq!(enc.encode(m), cb.encode(m), "{dt:?} tie at mid");
            let lo = f32::from_bits(m.to_bits().wrapping_sub(1));
            assert_eq!(enc.encode(lo), cb.encode(lo), "{dt:?} below mid");
        }
    }
}
