//! KV-cached decode equivalence + continuous batching, over real
//! artifacts. The acceptance bar of the decode subsystem: greedy decoding
//! through the cached path must be byte-identical to the full-recompute
//! fallback, and continuous batching must preserve per-prompt outputs
//! versus sequential generation. Each test skips with a message when
//! artifacts (or their decode graphs) are not built, so `cargo test -q`
//! is green from a fresh clone.

use std::rc::Rc;

use qlora::engine::{DecodeMode, Engine, Sampler};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;

// PjRtClient is single-threaded (Rc internally), so each test builds its
// own runtime; executable compilation is cached per-runtime only.
fn env() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!(
            "skipped: artifacts not built in {dir:?} — run `make artifacts` \
             to exercise the decode tests"
        );
        return None;
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipped: PJRT CPU runtime unavailable: {e:#}");
            return None;
        }
    };
    Some((Rc::new(rt), manifest))
}

/// The e2e engine, or `None` (with a message) when its decode graphs are
/// missing — e.g. artifacts from before the KV-cache change.
fn cached_engine(rt: &Rc<Runtime>, manifest: &Manifest) -> Option<Engine> {
    let eng = Engine::new(rt.clone(), manifest, "e2e").ok()?;
    if !eng.has_cached_decode() {
        eprintln!(
            "skipped: artifact \"e2e\" has no decode graphs — re-run \
             `make artifacts`"
        );
        return None;
    }
    Some(eng)
}

const PROMPTS: [&str; 5] =
    ["copy ab", "rev abcd", "up hi", "copy qlora engine", "rev x"];

#[test]
fn cached_greedy_is_byte_identical_to_full() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = cached_engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 12, ..Sampler::default() };
    let mut full = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .decode(DecodeMode::Full)
        .build()
        .unwrap();
    let mut cached = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .decode(DecodeMode::Cached)
        .build()
        .unwrap();
    for p in PROMPTS {
        let a = full.generate(p).unwrap();
        let b = cached.generate(p).unwrap();
        assert_eq!(a, b, "cached decode diverged from full on {p:?}");
    }
}

#[test]
fn cached_batch_matches_full_batch() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = cached_engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let prompts = &PROMPTS[..3];
    let mut full = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .decode(DecodeMode::Full)
        .build()
        .unwrap();
    let mut cached = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .decode(DecodeMode::Cached)
        .build()
        .unwrap();
    assert_eq!(
        full.generate_batch(prompts).unwrap(),
        cached.generate_batch(prompts).unwrap()
    );
}

#[test]
fn continuous_batching_preserves_per_prompt_outputs() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = cached_engine(&rt, &manifest) else { return };
    let batch = eng.spec.cfg.batch;
    // more prompts than rows: rows must retire and re-admit mid-flight,
    // interleaving prefills of late prompts with decode steps of early
    // ones — each output must still equal the prompt decoded alone
    let prompts: Vec<String> = (0..batch + 3)
        .map(|i| format!("rev p{i}"))
        .collect();
    let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
    for mode in [DecodeMode::Cached, DecodeMode::Full] {
        let sampler = Sampler { max_new_tokens: 6, ..Sampler::default() };
        let mut s = eng
            .session()
            .sampler(sampler)
            .greedy(true)
            .decode(mode)
            .build()
            .unwrap();
        let batched = s.generate_batch(&refs).unwrap();
        assert_eq!(batched.len(), refs.len());
        for (p, b) in refs.iter().zip(batched.iter()) {
            let single = s.generate(p).unwrap();
            assert_eq!(&single, b, "{mode:?}: row for {p:?} diverged");
        }
    }
}

#[test]
fn cached_streaming_matches_full_generation() {
    let Some((rt, manifest)) = env() else { return };
    let Some(eng) = cached_engine(&rt, &manifest) else { return };
    let sampler = Sampler { max_new_tokens: 8, ..Sampler::default() };
    let mut full = eng
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .decode(DecodeMode::Full)
        .build()
        .unwrap();
    let whole = full.generate("copy ab").unwrap();
    let mut cached = eng
        .session()
        .sampler(sampler)
        .greedy(true)
        .decode(DecodeMode::Cached)
        .build()
        .unwrap();
    let mut streamed = String::new();
    let mut stream = cached.stream("copy ab").unwrap();
    while let Some(piece) = stream.next_token_text() {
        streamed.push_str(&piece.unwrap());
    }
    assert_eq!(whole, streamed);
}

#[test]
fn zero_token_budget_returns_empty_without_stepping() {
    let Some((rt, manifest)) = env() else { return };
    let Ok(eng) = Engine::new(rt.clone(), &manifest, "e2e") else { return };
    let sampler = Sampler { max_new_tokens: 0, ..Sampler::default() };
    let mut s = eng.session().sampler(sampler).greedy(true).build().unwrap();
    let outs = s.generate_batch(&["copy ab", "rev cd"]).unwrap();
    assert_eq!(outs, vec![String::new(), String::new()]);
    assert_eq!(s.tokens_generated(), 0);
}

#[test]
fn forcing_cached_mode_without_decode_graphs_is_a_clear_error() {
    let Some((rt, manifest)) = env() else { return };
    // train-only artifact: no fwd/prefill/decode graphs at all
    let Ok(eng) = Engine::new(rt.clone(), &manifest, "tiny_scope_all") else {
        return;
    };
    assert!(!eng.has_cached_decode());
    let mut s = eng
        .session()
        .decode(DecodeMode::Cached)
        .greedy(true)
        .build()
        .unwrap();
    let err = match s.generate("copy ab") {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("cached decode over a train-only artifact"),
    };
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
}
