//! Property-style tests for the request-lifecycle scheduler: randomized
//! submit/admit/push/cancel/deadline/retire interleavings (driven by the
//! repo's deterministic RNG — no artifacts, no runtime) must preserve the
//! core serving invariants:
//!
//! 1. every job reaches **exactly one** terminal [`JobOutcome`] — never a
//!    silent empty result, never two outcomes;
//! 2. results come back in **submission order** (job `i`'s tokens are job
//!    `i`'s tokens, checked by stamping each push with its job id);
//! 3. the **resident-token budget is never exceeded**: the sum of
//!    reserved (`prompt + max_new`) tokens across resident rows stays at
//!    or below the admission budget at every step (resident `prompt +
//!    generated` is bounded by reserved, so it is covered too);
//! 4. row misuse (out-of-range, double retire) is an `Err`, not a panic.
//!
//! The driving loop mirrors `Session::serve_with` exactly: poll →
//! admit → retire-exhausted → step (push or EOS-retire), with time
//! fabricated instead of wall-clock so deadlines are deterministic.

use std::time::{Duration, Instant};

use qlora::engine::scheduler::{
    JobOutcome, Priority, Request, Scheduler,
};
use qlora::paged::BlockConfig;
use qlora::util::faults::{FaultPlan, FaultSite, Faults};
use qlora::util::rng::Rng;

/// Everything the test remembers about one submitted job.
struct Spec {
    max_new: usize,
    cancel_at_step: Option<usize>,
    has_deadline: bool,
    handle: qlora::engine::CancelHandle,
}

fn random_priority(rng: &mut Rng) -> Priority {
    match rng.below(3) {
        0 => Priority::Low,
        1 => Priority::Normal,
        _ => Priority::High,
    }
}

/// One randomized serving run; returns whether it took the abort path.
fn run_case(seed: u64) -> bool {
    let mut rng = Rng::new(seed);
    let capacity = 1 + rng.below(4);
    let seq_len = 8 + rng.below(24); // 8..32
    let n_jobs = 1 + rng.below(12);
    // budget ≥ seq_len so every single job fits (mirrors the session,
    // which clamps max_new to seq_len - prompt_len): the invariant below
    // can then be asserted strictly, with no sole-tenant carve-out
    let budget = if rng.below(3) == 0 {
        usize::MAX
    } else {
        seq_len + rng.below(3 * seq_len)
    };
    let mut sched = Scheduler::with_budget(capacity, budget);
    let abort_at = (rng.below(4) == 0).then(|| rng.below(30));

    // jobs trickle in: each has a submission step, some get cancelled at
    // a later step, some carry (sometimes already-tight) deadlines
    let mut arrivals: Vec<(usize, Request)> = Vec::new();
    let mut specs: Vec<Spec> = Vec::new();
    for _ in 0..n_jobs {
        let at_step = rng.below(12);
        let prompt_len = 1 + rng.below(seq_len - 1);
        let max_new = rng.below(seq_len - prompt_len + 1);
        let mut req = Request::new(vec![0; prompt_len], max_new)
            .priority(random_priority(&mut rng));
        let has_deadline = rng.below(4) == 0;
        if has_deadline {
            req = req.deadline(Duration::from_millis(rng.below(60) as u64));
        }
        arrivals.push((at_step, req));
        specs.push(Spec {
            max_new,
            cancel_at_step: (rng.below(5) == 0).then(|| rng.below(25)),
            has_deadline,
            handle: qlora::engine::CancelHandle::new(),
        });
    }

    // fabricated clock: 1-4 ms per loop iteration
    let mut now = Instant::now();
    let mut step = 0usize;
    let mut submitted = vec![false; n_jobs];
    // scheduler job ids follow *submission* order, which differs from
    // the arrivals order when arrival steps differ — map back to specs
    let mut spec_of_job: Vec<usize> = Vec::new();
    let mut aborted = false;
    loop {
        let all_submitted = submitted.iter().all(|&s| s);
        if all_submitted && sched.finished() {
            break;
        }
        if abort_at == Some(step) {
            aborted = true;
            break;
        }
        assert!(step < 10_000, "livelock: case {seed} never finished");
        now += Duration::from_millis(1 + rng.below(4) as u64);

        for (id, (at, req)) in arrivals.iter().enumerate() {
            if *at == step.min(11) && !submitted[id] {
                let (jid, _) = sched.submit_with_handle(
                    req.clone(),
                    specs[id].handle.clone(),
                    now,
                );
                assert_eq!(jid, spec_of_job.len(), "ids are submission order");
                spec_of_job.push(id);
                submitted[id] = true;
            }
        }
        for spec in &specs {
            if spec.cancel_at_step == Some(step) {
                spec.handle.cancel();
            }
        }

        // --- the serve loop, verbatim ---
        sched.poll(now);
        sched.admit(now);
        // invariant 3: the budget gates admission at every step
        assert!(
            sched.reserved_tokens() <= budget,
            "case {seed}: reserved {} > budget {budget}",
            sched.reserved_tokens()
        );
        assert!(
            sched.resident_tokens() <= sched.reserved_tokens(),
            "case {seed}: resident above reserved"
        );
        for row in sched.active_rows() {
            if sched.budget_exhausted(row, seq_len) {
                sched.retire(row).unwrap();
            }
        }
        for row in sched.active_rows() {
            let id = sched.job_in(row).expect("active row has a job");
            if rng.below(8) == 0 {
                sched.retire(row).unwrap(); // "EOS"
            } else {
                // stamp every token with its job id (invariant 2)
                sched.push(row, 1000 + id as i32, now).unwrap();
            }
        }
        step += 1;
    }

    let results = sched.take_results();
    // invariant 1: exactly one terminal outcome per submitted job
    let n_submitted = submitted.iter().filter(|&&s| s).count();
    assert_eq!(
        results.len(),
        n_submitted,
        "case {seed}: every submitted job must appear exactly once"
    );
    for (id, r) in results.iter().enumerate() {
        // invariant 2: job i's slot holds only job i's tokens
        assert!(
            r.tokens.iter().all(|&t| t == 1000 + id as i32),
            "case {seed}: job {id} result holds foreign tokens {:?}",
            r.tokens
        );
        let spec = &specs[spec_of_job[id]];
        assert!(
            r.tokens.len() <= spec.max_new,
            "case {seed}: job {id} overran its max_new"
        );
        if !aborted {
            assert_ne!(
                r.outcome,
                JobOutcome::Aborted,
                "case {seed}: completed run may not leave Aborted jobs"
            );
        }
        // a job nobody interfered with must finish normally
        if !aborted && spec.cancel_at_step.is_none() && !spec.has_deadline {
            assert_eq!(
                r.outcome,
                JobOutcome::Done,
                "case {seed}: undisturbed job {id} must end Done"
            );
        }
    }
    aborted
}

#[test]
fn randomized_lifecycles_preserve_scheduler_invariants() {
    let mut saw_abort = false;
    for case in 0..120u64 {
        saw_abort |= run_case(0xC0FFEE ^ case);
    }
    assert!(saw_abort, "abort path never exercised — widen the sampling");
}

/// One randomized blocks-mode run with a seeded `block-alloc` fault
/// schedule interleaved with deadlines and the decode-step watchdog.
/// Injected allocation failures surface as ordinary pool pressure
/// (swap-out, lost-token resume), so every lifecycle invariant must
/// hold unchanged: exactly one typed outcome per job, no foreign
/// tokens, block-pool consistency after every step, and no livelock
/// (fault caps guarantee the schedule eventually dries up).
fn run_fault_case(seed: u64) {
    let mut rng = Rng::new(seed);
    let capacity = 1 + rng.below(3);
    let seq_len = 12 + rng.below(12); // 12..24
    let block_tokens = 2 + rng.below(4); // 2..6
    let per_row = seq_len.div_ceil(block_tokens);
    // roomy enough that nothing is Aborted for sheer size; pressure
    // comes from the injected faults and from co-residents
    let n_blocks = per_row * (capacity + 1);
    let n_jobs = 2 + rng.below(8);
    let plan = FaultPlan { seed: seed ^ 0xFA17, ..FaultPlan::default() }
        .with(
            FaultSite::BlockAlloc,
            0.05 + 0.4 * rng.f64(),
            Some(1 + rng.below(20) as u64), // capped: schedules dry up
        );
    let mut sched = Scheduler::with_blocks(
        capacity,
        BlockConfig::new(block_tokens, n_blocks),
    )
    .unwrap();
    sched.set_faults(Faults::new(&plan));
    sched.set_watchdog(Some(Duration::from_millis(40)));

    let mut now = Instant::now();
    let mut had_deadline = Vec::new();
    for _ in 0..n_jobs {
        let prompt_len = 1 + rng.below(seq_len / 2);
        let max_new = rng.below(seq_len - prompt_len + 1);
        let mut req = Request::new(vec![0; prompt_len], max_new)
            .priority(random_priority(&mut rng));
        let deadline = rng.below(3) == 0;
        if deadline {
            req = req
                .deadline(Duration::from_millis(20 + rng.below(80) as u64));
        }
        had_deadline.push(deadline);
        sched.submit(req, now);
    }
    let mut steps = 0usize;
    while !sched.finished() {
        assert!(
            steps < 10_000,
            "livelock: fault case {seed} never finished"
        );
        now += Duration::from_millis(1 + rng.below(4) as u64);
        sched.poll(now);
        sched.admit(now);
        sched.take_swap_outs();
        for row in sched.active_rows() {
            if sched.budget_exhausted(row, seq_len) {
                sched.retire(row).unwrap();
            }
        }
        for row in sched.active_rows() {
            // an earlier push this step may have swapped this row out
            let Some(id) = sched.job_in(row) else { continue };
            if rng.below(8) == 0 {
                sched.retire(row).unwrap(); // "EOS"
            } else {
                sched.push(row, 1000 + id as i32, now).unwrap();
            }
        }
        sched.take_swap_outs();
        sched.check_block_invariants();
        steps += 1;
    }
    let results = sched.take_results();
    assert_eq!(
        results.len(),
        n_jobs,
        "fault case {seed}: every job must get exactly one outcome"
    );
    for (id, r) in results.iter().enumerate() {
        assert!(
            r.tokens.iter().all(|&t| t == 1000 + id as i32),
            "fault case {seed}: job {id} result holds foreign tokens {:?}",
            r.tokens
        );
        assert_ne!(
            r.outcome,
            JobOutcome::Aborted,
            "fault case {seed}: injected alloc faults must degrade to \
             pressure, never abort"
        );
        if !had_deadline[id] {
            assert!(
                matches!(
                    r.outcome,
                    JobOutcome::Done | JobOutcome::TimedOut
                ),
                "fault case {seed}: job {id} without a deadline ended {:?}",
                r.outcome
            );
        }
    }
}

#[test]
fn injected_block_alloc_faults_with_deadlines_preserve_invariants() {
    for case in 0..60u64 {
        run_fault_case(0x00FA_0175 ^ case);
    }
}

#[test]
fn random_row_misuse_never_panics() {
    let mut rng = Rng::new(7);
    let now = Instant::now();
    for _ in 0..50 {
        let capacity = 1 + rng.below(3);
        let mut sched = Scheduler::with_budget(capacity, 64);
        for _ in 0..200 {
            match rng.below(6) {
                0 => {
                    let len = 1 + rng.below(6);
                    sched.submit(Request::new(vec![1; len], rng.below(8)), now);
                }
                1 => {
                    sched.admit(now);
                }
                2 => {
                    // rows may be free, active, or out of range — all fine
                    let _ = sched.push(rng.below(capacity + 3), 1, now);
                }
                3 => {
                    let _ = sched.retire(rng.below(capacity + 3));
                }
                4 => {
                    sched.poll(now);
                }
                _ => {
                    let row = rng.below(capacity + 3);
                    let _ = sched.out_len(row);
                    let _ = sched.total_len(row);
                    let _ = sched.budget_exhausted(row, 16);
                    let _ = sched.job_in(row);
                    let _ = sched.stats();
                }
            }
        }
        // whatever state the fuzz left behind, results are still typed
        let n = sched.stats().submitted as usize;
        assert_eq!(sched.take_results().len(), n);
    }
}
