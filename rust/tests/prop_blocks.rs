//! Property tests for the unified KV block manager and the scheduler's
//! block-granular admission (no artifacts, no runtime — pure
//! accounting). `python/tests/test_blocks_mirror.py` re-runs the same
//! invariants against an independent Python port of the model, per the
//! repo's cross-language verification discipline.
//!
//! Invariants:
//!
//! 1. **refcounts never leak**: every block a row table references is
//!    counted exactly once per reference, and after every row detaches
//!    the pool is empty with allocations == frees;
//! 2. **CoW never mutates a shared block**: each row's concatenated
//!    block contents equal its own externally-tracked history at every
//!    step, no matter how other rows share, append, fork, or release;
//! 3. **block-granular reserved ≤ budget at every step**: the scheduler
//!    in blocks mode never lets `kv_blocks_in_use` exceed the pool;
//! 4. the shared-prefix workload admits strictly more concurrent rows
//!    than the dense `prompt + max_new` reservation at the same token
//!    budget (the over-reserving admission bug this PR fixes);
//! 5. final results are bit-identical with prefix sharing on and off.

use std::time::{Duration, Instant};

use qlora::engine::scheduler::{JobOutcome, Request, Scheduler};
use qlora::paged::{AppendOutcome, BlockConfig, BlockManager};
use qlora::util::prop::{check, default_cases};

/// Assert every row's physical contents match its mirrored history and
/// the manager's own structural invariants hold.
fn assert_mirrors(m: &BlockManager, expected: &[Option<Vec<i32>>]) {
    m.check_invariants();
    for (row, exp) in expected.iter().enumerate() {
        assert_eq!(
            m.row_tokens(row).as_ref(),
            exp.as_ref(),
            "row {row} content diverged from its own history"
        );
    }
}

#[test]
fn refcounts_never_leak_and_cow_never_mutates_shared_blocks() {
    check("block-manager lifecycle", default_cases(), |rng| {
        let block_tokens = 1 + rng.below(4);
        let n_blocks = 4 + rng.below(28);
        let n_rows = 1 + rng.below(6);
        let mut cfg = BlockConfig::new(block_tokens, n_blocks);
        cfg.prefix_sharing = rng.below(4) != 0; // mostly on, sometimes off
        cfg.bytes_per_block = 64 * block_tokens;
        let mut m = BlockManager::new(cfg).unwrap();
        // the test's own source of truth: what each attached row's
        // history must read back as, maintained independently
        let mut expected: Vec<Option<Vec<i32>>> = vec![None; n_rows];
        // a handful of canned prefixes so random attaches collide (that
        // is what exercises sharing); tiny vocab so identical *content*
        // under different parents shows up too
        let prefixes: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                (0..block_tokens * (1 + rng.below(3)))
                    .map(|_| rng.below(5) as i32)
                    .collect()
            })
            .collect();
        for _ in 0..300 {
            let row = rng.below(n_rows);
            match (expected[row].is_some(), rng.below(10)) {
                // attach a free row: canned prefix + random tail
                (false, _) => {
                    let mut hist = prefixes[rng.below(3)].clone();
                    for _ in 0..rng.below(2 * block_tokens) {
                        hist.push(rng.below(5) as i32);
                    }
                    let need = m.probe_attach(&hist);
                    if need > m.free_blocks() {
                        assert!(
                            m.attach(row, &hist).is_err(),
                            "attach past the pool must refuse"
                        );
                    } else {
                        let total = m.cfg().blocks_for(hist.len());
                        let shared = m.attach(row, &hist).unwrap();
                        assert_eq!(shared + need, total, "probe == attach");
                        expected[row] = Some(hist);
                    }
                }
                // release or swap out a live row
                (true, 0) => {
                    m.release_row(row).unwrap();
                    expected[row] = None;
                }
                (true, 1) => {
                    m.swap_out(row).unwrap();
                    expected[row] = None;
                }
                // append: the dominant op, as in real decode
                (true, _) => {
                    let tok = rng.below(5) as i32;
                    match m.append(row, tok).unwrap() {
                        AppendOutcome::Appended { .. } => {
                            expected[row].as_mut().unwrap().push(tok);
                        }
                        AppendOutcome::NeedBlock => {
                            assert_eq!(
                                m.free_blocks(),
                                0,
                                "NeedBlock only when the pool is empty"
                            );
                        }
                    }
                }
            }
            assert_mirrors(&m, &expected);
        }
        // drain: every row detaches, nothing may remain allocated
        for row in 0..n_rows {
            if expected[row].take().is_some() {
                m.release_row(row).unwrap();
            }
        }
        assert_mirrors(&m, &expected);
        assert_eq!(m.blocks_in_use(), 0, "all blocks returned");
        assert_eq!(m.shared_entries(), 0, "share map drained with the pool");
        let (allocated, freed) = m.totals();
        assert_eq!(allocated, freed, "every allocation was freed");
    });
}

/// Drive a blocks-mode scheduler exactly like `Session::serve_with`
/// (poll → admit → drain swap-outs → retire-exhausted → step), pushing
/// a token that is a pure function of (job, position) so outputs are
/// schedule-independent. Returns (results, shared hits, swap-outs).
fn run_blocks_case(
    cfg: BlockConfig,
    capacity: usize,
    seq_len: usize,
    jobs: &[(Vec<i32>, usize)],
) -> (Vec<(JobOutcome, Vec<i32>)>, u64, u64) {
    let mut sched = Scheduler::with_blocks(capacity, cfg).unwrap();
    let mut now = Instant::now();
    for (prompt, max_new) in jobs {
        sched.submit(Request::new(prompt.clone(), *max_new), now);
    }
    let mut steps = 0;
    while !sched.finished() {
        steps += 1;
        assert!(steps < 10_000, "livelock: blocks-mode serve never drained");
        now += Duration::from_millis(1);
        sched.poll(now);
        sched.admit(now);
        sched.take_swap_outs();
        let s = sched.stats();
        // invariant 3: blocks actually in use never exceed the pool
        assert!(
            s.kv_blocks_in_use <= s.kv_blocks,
            "{} blocks in use > pool of {}",
            s.kv_blocks_in_use,
            s.kv_blocks
        );
        for row in sched.active_rows() {
            if sched.budget_exhausted(row, seq_len) {
                sched.retire(row).unwrap();
            }
        }
        for row in sched.active_rows() {
            // an earlier push this step may have swapped this row out
            let Some(id) = sched.job_in(row) else { continue };
            let tok = (1000 * (id as i32 + 1)) + sched.out_len(row) as i32;
            sched.push(row, tok, now).unwrap();
        }
        sched.take_swap_outs();
    }
    let s = sched.stats();
    let results = sched
        .take_results()
        .into_iter()
        .map(|r| (r.outcome, r.tokens))
        .collect();
    (results, s.shared_block_hits, s.swap_outs)
}

#[test]
fn blocks_mode_scheduling_preserves_job_lifecycles_under_pressure() {
    check("blocks-mode scheduler", default_cases(), |rng| {
        let block_tokens = 1 + rng.below(4);
        let seq_len = 8 + rng.below(24);
        let capacity = 1 + rng.below(4);
        // pool always covers one full row (the session builder enforces
        // the same floor), plus random slack so pressure varies by case
        let per_row = seq_len.div_ceil(block_tokens);
        let cfg = BlockConfig::new(block_tokens, per_row + rng.below(16));
        let shared: Vec<i32> =
            (0..1 + rng.below(seq_len / 2)).map(|i| i as i32).collect();
        let jobs: Vec<(Vec<i32>, usize)> = (0..1 + rng.below(10))
            .map(|_| {
                let mut prompt = if rng.below(2) == 0 {
                    shared.clone()
                } else {
                    vec![rng.below(100) as i32]
                };
                while prompt.len() < seq_len && rng.below(3) != 0 {
                    prompt.push(rng.below(100) as i32);
                }
                let max_new = rng.below(seq_len - prompt.len() + 1);
                (prompt, max_new)
            })
            .collect();
        let (results, _, _) =
            run_blocks_case(cfg, capacity, seq_len, &jobs);
        assert_eq!(results.len(), jobs.len(), "one outcome per job");
        for (id, (outcome, tokens)) in results.iter().enumerate() {
            // nothing interferes with these jobs: all must finish, with
            // exactly their own stamped tokens (swap/resume included)
            assert_eq!(*outcome, JobOutcome::Done, "job {id}");
            let want: Vec<i32> = (0..jobs[id].1)
                .map(|i| 1000 * (id as i32 + 1) + i as i32)
                .collect();
            assert_eq!(*tokens, want, "job {id} tokens survived swaps");
        }
    });
}

#[test]
fn shared_prefix_workload_admits_more_rows_than_dense_reservation() {
    let now = Instant::now();
    let prefix = vec![7i32; 24];
    let jobs: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut p = prefix.clone();
            p.push(100 + i as i32);
            p
        })
        .collect();
    // dense baseline: every row reserves prompt + max_new = 29 tokens
    // up front, so a 64-token budget fits only 2 of the 6
    let mut dense = Scheduler::with_budget(8, 64);
    for p in &jobs {
        dense.submit(Request::new(p.clone(), 4), now);
    }
    let dense_admitted = dense.admit(now).len();
    assert_eq!(dense_admitted, 2, "worst-case reservation admits 2");
    // block-granular admission over the *same* 64 tokens of KV: the 24
    // shared prefix tokens are stored once, so each extra row costs one
    // private block instead of 29 reserved tokens
    let mut blocks =
        Scheduler::with_blocks(8, BlockConfig::for_token_budget(64, 8))
            .unwrap();
    for p in &jobs {
        blocks.submit(Request::new(p.clone(), 4), now);
    }
    let blocks_admitted = blocks.admit(now).len();
    assert!(
        blocks_admitted > dense_admitted,
        "prefix sharing must admit strictly more rows \
         ({blocks_admitted} vs {dense_admitted})"
    );
    let s = blocks.stats();
    assert!(s.shared_block_hits > 0, "the prefix actually got shared");
    assert!(s.kv_blocks_in_use <= s.kv_blocks);
}

#[test]
fn results_are_bit_identical_with_prefix_sharing_on_and_off() {
    // tight pool (two rows' worth for four concurrent jobs) so the run
    // crosses swap-outs/resumes; tokens are a pure function of (job,
    // position), so any lost or cross-wired output breaks equality
    let seq_len = 24;
    let jobs: Vec<(Vec<i32>, usize)> = (0..4)
        .map(|i| {
            let mut p = vec![3i32; 8];
            p.push(50 + i as i32);
            (p, 6)
        })
        .collect();
    let run = |sharing: bool| {
        let mut cfg = BlockConfig::new(4, 12);
        cfg.prefix_sharing = sharing;
        run_blocks_case(cfg, 4, seq_len, &jobs)
    };
    let (with, hits_on, _) = run(true);
    let (without, hits_off, _) = run(false);
    assert_eq!(with, without, "outputs must not depend on sharing");
    assert!(hits_on > 0, "sharing-on run actually shared blocks");
    assert_eq!(hits_off, 0, "sharing-off run must not share");
    for (outcome, tokens) in &with {
        assert_eq!(*outcome, JobOutcome::Done, "all jobs complete");
        assert_eq!(tokens.len(), 6);
    }
}
