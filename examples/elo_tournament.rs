//! Chatbot-evaluation demo: run the paper's tournament protocol
//! (section 5.2) — GPT-4 and human judge models, Elo over 10k random
//! orderings, agreement statistics — and print Tables 1 and 7.
//!
//! Run: `cargo run --release --example elo_tournament -- [--fast]`

use anyhow::Result;

use qlora::experiments::{runner, Ctx};
use qlora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let ctx = Ctx {
        rt: None,
        manifest: None,
        seed: args.u64_or("seed", 42)?,
        fast: args.flag("fast"),
    };
    let results = std::path::PathBuf::from("results");
    for id in ["table1", "table7", "table12_13"] {
        println!("{}", runner::run_one(id, &ctx, &results)?);
    }
    Ok(())
}
