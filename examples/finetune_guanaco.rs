//! End-to-end driver: "train Guanaco-tiny" — and serve it.
//!
//! The full system composed: synthetic OASST1-style conversation-tree
//! corpus (top-reply selection, paper section 5.1) → group-by-length
//! batching (Appendix B.2) → one `engine::Engine` owning the frozen
//! NF4+DQ base of the `e2e` model, with the `Trainer` as its client
//! (LoRA on all linears, Adam on adapters only, gradient checkpointing,
//! paged-optimizer simulation attached) → held-out evaluation
//! before/after → the trained adapters *published back into the engine*
//! and sampled next to the untouched base adapter — the paper's
//! one-base/many-adapters economy in one run.
//!
//! Run: `cargo run --release --example finetune_guanaco -- [--steps 300]`
//! Results recorded in EXPERIMENTS.md section E2E.

use std::path::PathBuf;

use anyhow::Result;

use qlora::coordinator::checkpoint;
use qlora::coordinator::trainer::{TrainOptions, Trainer};
use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use qlora::data::tokenizer::Tokenizer;
use qlora::engine::{Engine, BASE_ADAPTER};
use qlora::runtime::artifact::Manifest;
use qlora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let steps = args.usize_or("steps", 300)?;
    let artifact = args.get_or("artifact", "e2e");
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let engine = Engine::cpu(&manifest, &artifact)?;
    let mut trainer = Trainer::new(&engine)?;
    let cfg = trainer.spec().cfg.clone();
    println!(
        "guanaco-tiny: {} params, quant={} (+DQ), LoRA r={} on {} layers, \
         batch {}x{}",
        cfg.n_params(), cfg.quant, cfg.lora_r, cfg.lora_scope, cfg.batch,
        cfg.seq_len
    );

    // OASST1-style corpus: ranked conversation trees, top-reply selection
    let ds = corpus(CorpusKind::Oasst1, 600, 1234);
    let tok = Tokenizer::new(cfg.vocab);
    let batcher = Batcher::new(&ds, tok.clone(), cfg.batch, cfg.seq_len,
                               false);
    let eval_ds = eval_set(EvalSuite::VicunaProxy, cfg.batch * 6, 77);
    let eval_b = Batcher::new(&eval_ds, tok.clone(), cfg.batch, cfg.seq_len,
                              false);

    let (loss0, acc0) = trainer.eval_all(&eval_b, 0)?;
    println!("before: eval loss {loss0:.4}, token accuracy {acc0:.3}");

    let opts = TrainOptions {
        steps,
        eval_every: (steps / 6).max(1),
        seed: 7,
        paged: true,
        device_budget: 48 << 20, // tight budget: exercise the pager
    };
    let t0 = std::time::Instant::now();
    let log = trainer.train(&batcher, Some(&eval_b), &opts)?;
    let dt = t0.elapsed();

    let (loss1, acc1) = trainer.eval_all(&eval_b, 0)?;
    println!(
        "after {steps} steps ({:.1}s, {:.0} ms/step): eval loss \
         {loss1:.4}, token accuracy {acc1:.3}",
        dt.as_secs_f64(),
        log.mean_step_time().as_secs_f64() * 1e3
    );
    println!("loss curve: first {:.3} -> smoothed final {:.3}",
             log.losses.first().unwrap(),
             log.smoothed_final_loss(20));
    for e in &log.evals {
        println!("  eval@{:<4} loss {:.4} acc {:.3}", e.step, e.loss,
                 e.accuracy);
    }
    if let Some(p) = &log.pager_stats {
        println!(
            "paged optimizer: {} faults, {} evictions, {} spike steps, \
             stall {:.2} ms total",
            p.faults, p.evictions, p.spike_steps, p.stall_us / 1e3
        );
    }

    std::fs::create_dir_all("results")?;
    log.write_csv(&PathBuf::from("results/e2e_loss.csv"))?;
    checkpoint::save_adapters(&trainer, &PathBuf::from(
        "results/guanaco_tiny_adapters.tensors"))?;
    println!("loss curve -> results/e2e_loss.csv; adapters -> \
              results/guanaco_tiny_adapters.tensors");

    // publish the trained adapters into the engine's registry and serve
    // them next to the untouched base adapter — two models, one frozen
    // base, zero re-uploads
    trainer.publish_adapter("guanaco-tiny")?;
    for adapter in [BASE_ADAPTER, "guanaco-tiny"] {
        let mut session =
            engine.session().adapter(adapter).greedy(true).seed(3).build()?;
        for prompt in ["copy abc", "rev abcd", "up ok"] {
            let out = session.generate(prompt)?;
            println!("  [{adapter}] {prompt:?} -> {out:?}");
        }
    }

    assert!(loss1 < loss0, "training must reduce held-out loss");
    println!("finetune_guanaco OK (loss {loss0:.3} -> {loss1:.3}, acc \
              {acc0:.3} -> {acc1:.3})");
    Ok(())
}
