//! Memory planner: "will model X finetune on GPU Y?" — the practical
//! question QLoRA answers (paper Figure 1 / Figure 6 / appendix G).
//!
//! Run: `cargo run --release --example memory_planner -- [--seq 512]`

use anyhow::Result;

use qlora::memory::{llama_family, train_footprint, Strategy};
use qlora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let seq = args.usize_or("seq", 512)?;
    let batch = args.usize_or("batch", 1)?;
    let gpus: [(&str, f64); 4] = [
        ("RTX 4090 (24 GB)", 24.0),
        ("A6000 (48 GB)", 48.0),
        ("A100 (80 GB)", 80.0),
        ("8×A100 (640 GB)", 640.0),
    ];
    println!("finetuning memory plan (seq={seq}, batch={batch}):\n");
    println!("{:<6} {:<16} {:>9}  fits on", "model", "strategy", "GB");
    for spec in llama_family() {
        for (label, strat) in [
            ("Full-16bit", Strategy::Full16),
            ("LoRA-16bit", Strategy::LoRA16 { r: 64 }),
            ("QLoRA-4bit", Strategy::QLoRA4 { r: 64, double_quant: false }),
            ("QLoRA-4bit+DQ",
             Strategy::QLoRA4 { r: 64, double_quant: true }),
        ] {
            let f = train_footprint(&spec, strat, seq, batch);
            let fit = gpus
                .iter()
                .find(|(_, gb)| f.total_gb() <= *gb)
                .map(|(n, _)| *n)
                .unwrap_or("nothing single-node");
            println!("{:<6} {:<16} {:>9.1}  {}", spec.name, label,
                     f.total_gb(), fit);
        }
        println!();
    }
    println!(
        "headline: 65B Full-16bit {:.0} GB vs QLoRA+DQ {:.1} GB \
         (paper: >780 GB -> <48 GB)",
        train_footprint(&llama_family()[3], Strategy::Full16, seq, batch)
            .total_gb(),
        train_footprint(
            &llama_family()[3],
            Strategy::QLoRA4 { r: 64, double_quant: true },
            seq,
            batch
        )
        .total_gb()
    );
    Ok(())
}
