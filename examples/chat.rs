//! Interactive-ish chat with a finetuned guanaco-tiny: loads the `e2e`
//! artifact (+ optional adapter/state checkpoint from finetune_guanaco)
//! and answers prompts with the paper's sampling settings (nucleus
//! p = 0.9, temperature 0.7 — section 5.2).
//!
//! Run: `cargo run --release --example chat -- --prompt "rev hello"
//!       [--ckpt results/ckpt.tensors] [--greedy]`

use anyhow::Result;

use qlora::coordinator::checkpoint;
use qlora::coordinator::generate::Sampler;
use qlora::coordinator::trainer::Trainer;
use qlora::data::tokenizer::Tokenizer;
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::util::cli::Args;
use qlora::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let mut trainer = Trainer::new(&rt, &manifest,
                                   &args.get_or("artifact", "e2e"))?;
    if let Some(ck) = args.get("ckpt") {
        checkpoint::load(&mut trainer, &std::path::PathBuf::from(ck))?;
        println!("(loaded checkpoint {ck})");
    }
    let tok = Tokenizer::new(trainer.spec.cfg.vocab);
    let sampler = Sampler {
        top_p: args.f64_or("top-p", 0.9)?,
        temperature: args.f64_or("temperature", 0.7)?,
        max_new_tokens: args.usize_or("max-new", 24)?,
    };
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let prompts: Vec<String> = match args.get("prompt") {
        Some(p) => vec![p.to_string()],
        None => ["copy qlora", "rev abcd", "up hi", "add 3 4"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    for p in prompts {
        let out = sampler.generate(&trainer, &tok, &p, &mut rng,
                                   args.flag("greedy"))?;
        println!("user: {p}\nguanaco-tiny: {out}\n");
    }
    Ok(())
}
