//! Interactive-ish chat served by `qlora::engine`: one frozen quantized
//! base loaded once, any number of adapters multiplexed over it. Loads
//! the `e2e` artifact (+ optional adapter/state checkpoints) and answers
//! prompts with the paper's sampling settings (nucleus p = 0.9,
//! temperature 0.7 — section 5.2).
//!
//! Run: `cargo run --release --example chat -- --prompt "rev hello"
//!       [--ckpt results/ckpt.tensors] [--greedy] [--stream] [--compare]`
//!
//! With no `--prompt`, a 4-prompt demo runs through *batched* decoding
//! (one forward per step for all prompts). `--compare` answers each
//! prompt under every registered adapter — base and checkpoint — without
//! re-uploading the base, the paper's many-adapters serving economy.

use anyhow::Result;

use qlora::engine::{Engine, Sampler, BASE_ADAPTER};
use qlora::runtime::artifact::Manifest;
use qlora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let engine = Engine::cpu(&manifest, &args.get_or("artifact", "e2e"))?;
    if let Some(ck) = args.get("ckpt") {
        engine.load_adapter("ckpt", &std::path::PathBuf::from(ck))?;
        println!("(loaded adapter checkpoint {ck})");
    }
    let sampler = Sampler::from_args(&args, 24)?;
    let adapters = if args.flag("compare") {
        engine.adapter_names()
    } else if args.get("ckpt").is_some() {
        vec!["ckpt".to_string()]
    } else {
        vec![BASE_ADAPTER.to_string()]
    };
    let prompts: Vec<String> = match args.get("prompt") {
        Some(p) => vec![p.to_string()],
        None => ["copy qlora", "rev abcd", "up hi", "add 3 4"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };

    for adapter in &adapters {
        let mut session = engine
            .session()
            .adapter(adapter)
            .sampler(sampler.clone())
            .greedy(args.flag("greedy"))
            .seed(args.u64_or("seed", 0)?)
            .build()?;
        if prompts.len() > 1 {
            // batched decoding: all prompts advance per forward
            let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
            for (p, out) in refs.iter().zip(session.generate_batch(&refs)?) {
                println!("user: {p}\nguanaco-tiny[{adapter}]: {out}\n");
            }
        } else if args.flag("stream") {
            use std::io::Write;
            print!("user: {}\nguanaco-tiny[{adapter}]: ", prompts[0]);
            std::io::stdout().flush()?;
            session.generate_with(&prompts[0], |piece| {
                print!("{piece}");
                let _ = std::io::stdout().flush();
            })?;
            println!("\n");
        } else {
            let out = session.generate(&prompts[0])?;
            println!("user: {}\nguanaco-tiny[{adapter}]: {out}\n", prompts[0]);
        }
        println!(
            "({} tokens sampled under adapter {adapter:?})",
            session.tokens_generated()
        );
    }
    Ok(())
}
