//! Quickstart: the full QLoRA stack in one file.
//!
//! 1. Quantize a weight matrix to NF4 + Double Quantization in native Rust
//!    (paper section 3) and inspect the memory accounting.
//! 2. Load the *Pallas kernel* artifacts (L1, lowered to HLO by
//!    `make artifacts`), run them on the PJRT CPU client, and check the
//!    numerics against the Python-emitted test vectors — proving the
//!    pallas → HLO → PJRT path end to end.
//! 3. Stand up the serving engine over the `e2e` model: frozen base
//!    uploaded once, a `Session` decoding over the base adapter.
//!
//! Run: `cargo run --release --example quickstart`

use std::rc::Rc;

use anyhow::{Context, Result};

use qlora::engine::Engine;
use qlora::quant::codebook::DType;
use qlora::quant::QuantizedTensor;
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::runtime::executor::{literal_from_tensor, literal_to_f32};
use qlora::tensorio::{find, read_tensors};
use qlora::util::rng::Rng;

fn main() -> Result<()> {
    // ---- 1. native NF4 + DQ quantization --------------------------------
    let mut rng = Rng::new(0);
    let (h, o) = (256, 128);
    let w: Vec<f32> = rng.normal_vec_f32(h * o);
    let q = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))?;
    let back = q.dequantize()?;
    let mse: f64 = w
        .iter()
        .zip(back.iter())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.len() as f64;
    println!(
        "NF4+DQ quantization: {} params -> {} bytes \
         ({:.3} bits/param, paper: 4.127), round-trip MSE {mse:.5}",
        h * o,
        q.stored_bytes(),
        q.bits_per_param()
    );

    // ---- 2. Pallas kernels via PJRT --------------------------------------
    let dir = Manifest::default_dir();
    let manifest_path = dir.join("manifest.json");
    if !manifest_path.exists() {
        println!("(artifacts not built — run `make artifacts` to exercise \
                  the PJRT path)");
        return Ok(());
    }
    let rt = Rc::new(Runtime::cpu()?);
    let vectors = read_tensors(&dir.join("kernel_vectors.tensors"))
        .context("kernel vectors")?;

    // 2a. NF4 dequantize kernel
    let exe = rt.load_hlo(&dir.join("kernel_nf4_dequant.hlo.txt"))?;
    let codes = literal_from_tensor(find(&vectors, "dequant/codes")?)?;
    let absmax = literal_from_tensor(find(&vectors, "dequant/absmax")?)?;
    let out = exe.run(&[&codes, &absmax])?;
    let got = literal_to_f32(&out[0])?;
    let want = find(&vectors, "dequant/expected")?.to_f32()?;
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pallas nf4-dequant kernel via PJRT: {} values, max |err| = \
              {max_err:.2e}", got.len());
    assert!(max_err < 1e-5);

    // 2b. fused QLoRA matmul kernel (paper Eq. 5)
    let exe = rt.load_hlo(&dir.join("kernel_qlora_matmul.hlo.txt"))?;
    let inputs: Vec<xla::Literal> = ["qmm/x", "qmm/codes", "qmm/absmax",
                                     "qmm/a", "qmm/b"]
        .iter()
        .map(|n| literal_from_tensor(find(&vectors, n).unwrap()).unwrap())
        .collect();
    let refs: Vec<&xla::Literal> = inputs.iter().collect();
    let out = exe.run(&refs)?;
    let got = literal_to_f32(&out[0])?;
    let want = find(&vectors, "qmm/expected")?.to_f32()?;
    let max_err = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pallas fused qlora-matmul kernel via PJRT: Y = X·dd(W) + \
              s(X·L1)L2, max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3);

    // ---- 3. the serving engine -------------------------------------------
    match Manifest::load(&dir) {
        Ok(manifest) if manifest.get("e2e").is_ok() => {
            // frozen base uploaded once; sessions/adapters multiplex over it
            let engine = Engine::new(rt.clone(), &manifest, "e2e")?;
            let mut session = engine.session().greedy(true).build()?;
            let out = session.generate("copy qlora")?;
            println!(
                "engine serving \"e2e\" (adapters: {}): \"copy qlora\" -> \
                 {out:?} ({} tokens)",
                engine.adapter_names().join(", "),
                session.tokens_generated()
            );
        }
        _ => println!("(e2e artifact not built — skipping the engine demo)"),
    }

    println!("quickstart OK");
    Ok(())
}
